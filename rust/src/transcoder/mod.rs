//! The Network Transcoder (§6.2): translates MPI-Engine transfer plans
//! into per-transceiver NIC instructions — subnet (path), wavelength and
//! timeslots — with **no runtime scheduler**: every assignment is a pure
//! function of the plan and the topology ("schedule-less"), and the
//! resulting schedule is contention-free by construction (verified
//! mechanically by the fabric simulator over every operation — the paper's
//! "contention-less" claim).
//!
//! Resource model (one `b`-plane shown; planes are identical):
//! * a **subnet** is the passive coupler connecting transmitter group `t`
//!   of source communication group `g_src` to receiver group `t` of
//!   destination group `g_dst` — `x³` of them;
//! * within a subnet, each of the `Λ` wavelengths carries at most one
//!   transmission per timeslot (signals of all racks of the pair are
//!   broadcast-coupled — §3.1 "rack selection has not been performed");
//! * a transmitter group sends at most one (wavelength, subnet) per slot;
//! * a receiver group gates at most one source communication group per
//!   slot (the filtered SOA-gated `x:1` combiner).
//!
//! Transceiver-group selection follows Eq 2, `Trx = (g_src + g_dst +
//! j_src) mod x`, with the Eq 3–4 "additional transceiver groups" realized
//! as offsets in multiples of `J` (the offsets that cannot alias another
//! rack's base assignment).

use crate::collectives::plan::{CollectivePlan, Round};
use crate::topology::ramp::{NodeCoord, RampParams};
use anyhow::{ensure, Result};
use rustc_hash::FxHashMap as HashMap;

pub mod lanes;

/// Identity of a passive subnet: (source group, destination group,
/// transceiver group). `b` planes share instruction streams (§3.1), so the
/// plane index is implicit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SubnetId {
    pub src_group: usize,
    pub dst_group: usize,
    pub trx: usize,
}

/// One NIC instruction: transceiver group `trx` of `src` transmits on
/// `wavelength` through `subnet` during slots `[slot, slot + n_slots)`.
#[derive(Clone, Debug)]
pub struct NicInstruction {
    pub src: NodeCoord,
    pub dsts: Vec<NodeCoord>,
    pub trx: usize,
    pub subnet: SubnetId,
    pub wavelength: usize,
    pub slot: u64,
    pub n_slots: u64,
    pub bytes: u64,
}

/// A transcoded schedule: the full NIC instruction stream plus makespan.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    pub instructions: Vec<NicInstruction>,
    /// Total timeslots from first transmission to completion.
    pub total_slots: u64,
    /// Slot boundaries of each plan round (exclusive end), for latency
    /// accounting per algorithmic step.
    pub round_ends: Vec<u64>,
    /// Latency-bearing round boundaries: the chunk sub-rounds of a
    /// pipelined base round stream back-to-back on the wire (the
    /// nanosecond OCS re-targets between chunks without a fresh
    /// propagation delay), so they share one H2H. Equals
    /// `round_ends.len()` for unchunked plans; `0` means "not computed"
    /// (hand-built schedules) and falls back to `round_ends.len()`.
    pub h2h_rounds: usize,
}

impl Schedule {
    /// Wall-clock duration of the schedule on `p` (slots × slot time); the
    /// estimator adds per-round propagation/H2H on top.
    pub fn wire_time(&self, p: &RampParams) -> f64 {
        self.total_slots as f64 * p.slot_time
    }
}

/// Base transceiver-group for a source→destination pair (Eq 2).
pub fn base_trx(p: &RampParams, src: NodeCoord, dst: NodeCoord) -> usize {
    (src.g + dst.g + src.j) % p.x
}

/// Step-3 variant: `Trx = (g_src + j_dst) mod x`. Step 3's rack diagonals
/// alias under Eq 2 when `x` is even (`2j ≡ 2j' (mod x)` has two
/// solutions), putting two source groups on one receiver gate in the same
/// slot. The variant stays injective per transmitter (distinct `j_dst`),
/// per receiver (distinct `g_src`), and per (subnet, wavelength): a subnet
/// `(g_src, g_dst, t)` decodes uniquely to `j_dst = t − g_src`,
/// `ε = g_dst − j_dst`, `j_src = g_src − ε`.
pub fn base_trx_step3(p: &RampParams, src: NodeCoord, dst: NodeCoord) -> usize {
    (src.g + dst.j) % p.x
}

/// Base transceiver group given the producing subgroup step.
pub fn base_trx_for(
    p: &RampParams,
    step: Option<crate::collectives::subgroups::Step>,
    src: NodeCoord,
    dst: NodeCoord,
) -> usize {
    match step {
        Some(crate::collectives::subgroups::Step::S3) => base_trx_step3(p, src, dst),
        _ => base_trx(p, src, dst),
    }
}

/// The transceiver groups a transfer may stripe across: the base group
/// plus `q−1` offsets in multiples of `J` (Eqs 3–4 under the
/// rack-broadcast constraint), or all `x` groups for a Route & Select
/// step-4 pairwise exchange (§6.2.2 formula 1).
pub fn trx_groups(p: &RampParams, src: NodeCoord, dst: NodeCoord, q: usize) -> Vec<usize> {
    trx_groups_from_base(p, base_trx(p, src, dst), q, false)
}

fn trx_groups_from_base(p: &RampParams, base: usize, q: usize, dense: bool) -> Vec<usize> {
    if dense {
        // R&S step 4: consecutive offsets, up to all x groups
        let q = q.max(1).min(p.x);
        return (0..q).map(|k| (base + k) % p.x).collect();
    }
    let q = q.max(1).min((p.x / p.j).max(1));
    (0..q).map(|k| (base + k * p.j) % p.x).collect()
}

/// The receive wavelength of a node — fixed-receiver B&S: node `λ` of any
/// rack listens on channel `λ` (§4.1).
pub fn rx_wavelength(dst: NodeCoord) -> usize {
    dst.lambda
}

/// Payload bytes one transceiver *group* moves per timeslot (`b` planes in
/// parallel).
pub fn group_slot_payload(p: &RampParams) -> u64 {
    p.slot_payload_bytes() * p.b as u64
}

/// The transcoder: owns slot-occupancy state while transcoding one plan.
///
/// Wavelength-space granularity depends on the subnet kind (§3.1):
/// * **Broadcast & Select** — all racks of a group pair share the
///   subnet's wavelengths: occupancy key (subnet, λ);
/// * **Route & Select** — per-rack AWGRs + J×J crossbar: the AWGR input
///   constrains (subnet, λ, source rack) and the crossbar output
///   (subnet, λ, destination rack).
pub struct Transcoder<'a> {
    p: &'a RampParams,
    /// (subnet, wavelength, src rack or SHARED) → next free slot
    subnet_in_free: HashMap<(SubnetId, usize, usize), u64>,
    /// (subnet, wavelength, dst rack or SHARED) → next free slot
    subnet_out_free: HashMap<(SubnetId, usize, usize), u64>,
    /// (src flat id, trx) → next free slot
    tx_free: HashMap<(usize, usize), u64>,
    /// (dst flat id, trx) → next free slot (receiver gates one source
    /// group per slot)
    rx_free: HashMap<(usize, usize), u64>,
}

/// Rack key used when the subnet kind shares wavelengths across racks.
const SHARED_RACK: usize = usize::MAX;

fn rack_keys(p: &RampParams, src: NodeCoord, dst_rack: usize) -> (usize, usize) {
    match p.subnet_kind {
        crate::topology::ramp::SubnetKind::BroadcastSelect => (SHARED_RACK, SHARED_RACK),
        crate::topology::ramp::SubnetKind::RouteSelect => (src.j, dst_rack),
    }
}

impl<'a> Transcoder<'a> {
    pub fn new(p: &'a RampParams) -> Self {
        Self {
            p,
            subnet_in_free: HashMap::default(),
            subnet_out_free: HashMap::default(),
            tx_free: HashMap::default(),
            rx_free: HashMap::default(),
        }
    }

    /// Transcode a full collective plan into a NIC schedule. Rounds are
    /// synchronous: round `r+1` starts after round `r` completes.
    pub fn transcode(&mut self, plan: &CollectivePlan) -> Result<Schedule> {
        let mut sched = Schedule::default();
        let mut clock = 0u64;
        for step in &plan.steps {
            let q = step.trx_q.max(1);
            sched.h2h_rounds += step.base_rounds();
            for round in &step.rounds {
                clock = self.transcode_round(round, q, step.step, clock, &mut sched)?;
                sched.round_ends.push(clock);
            }
        }
        sched.total_slots = clock;
        Ok(sched)
    }

    /// Transcode a plan through a cross-step lane schedule: a task's
    /// chunk sub-rounds are released at its *dependencies'* completion
    /// slot (per-chunk edges across lane-aligned step boundaries — see
    /// [`lanes::LaneSchedule`]) instead of at the global round barrier,
    /// so chunk `c` of step `r+1` occupies the wire while chunk `c+1` of
    /// step `r` is still streaming. Physical resource constraints are
    /// still enforced by the occupancy maps, so the interleaved stream
    /// stays violation-free on the fabric; byte totals and H2H counts
    /// are schedule-invariant.
    pub fn transcode_lanes(
        &mut self,
        plan: &CollectivePlan,
        sched: &lanes::LaneSchedule,
    ) -> Result<Schedule> {
        sched.validate(plan)?;
        let mut out = Schedule::default();
        let mut task_end = vec![0u64; sched.tasks.len()];
        for (ti, task) in sched.tasks.iter().enumerate() {
            let release =
                sched.deps[ti].iter().map(|&d| task_end[d]).max().unwrap_or(0);
            let step = &plan.steps[task.step];
            let q = step.trx_q.max(1);
            let k = step.n_chunks.max(1);
            let chunked = k > 1 && step.rounds.len() % k == 0;
            let mut clock = release;
            if chunked {
                // this task owns chunk `task.chunk` of every base round
                for b in 0..step.rounds.len() / k {
                    let round = &step.rounds[b * k + task.chunk];
                    clock = self.transcode_round(round, q, step.step, clock, &mut out)?;
                    out.round_ends.push(clock);
                }
            } else {
                for round in &step.rounds {
                    clock = self.transcode_round(round, q, step.step, clock, &mut out)?;
                    out.round_ends.push(clock);
                }
            }
            task_end[ti] = clock;
            out.total_slots = out.total_slots.max(clock);
        }
        // H2H is a property of the base rounds, not of the interleaving
        out.h2h_rounds = plan.steps.iter().map(|s| s.base_rounds()).sum();
        Ok(out)
    }

    /// [`Self::transcode_lanes`] restricted to the *incomplete* chunk
    /// lanes: tasks whose chunk is marked done in `skip` emit no
    /// instructions and complete at their release slot (they gate
    /// nothing — their data already sits in the arena), so a resumed
    /// run's wire schedule carries exactly the bytes of the work that
    /// actually re-executes. Requires every step to be uniformly
    /// `skip.len()`-chunked (the same shape the event-driven lane
    /// executor demands of a resumable run).
    pub fn transcode_lanes_partial(
        &mut self,
        plan: &CollectivePlan,
        sched: &lanes::LaneSchedule,
        skip: &[bool],
    ) -> Result<Schedule> {
        sched.validate(plan)?;
        let k = skip.len();
        ensure!(k >= 1, "empty resume mask");
        for (i, step) in plan.steps.iter().enumerate() {
            ensure!(
                step.n_chunks.max(1) == k && step.rounds.len() % k == 0,
                "partial transcode of step {i}: plan is not uniformly {k}-chunked"
            );
        }
        let mut out = Schedule::default();
        let mut task_end = vec![0u64; sched.tasks.len()];
        for (ti, task) in sched.tasks.iter().enumerate() {
            let release =
                sched.deps[ti].iter().map(|&d| task_end[d]).max().unwrap_or(0);
            if skip[task.chunk] {
                task_end[ti] = release;
                continue;
            }
            let step = &plan.steps[task.step];
            let q = step.trx_q.max(1);
            let mut clock = release;
            for b in 0..step.rounds.len() / k {
                let round = &step.rounds[b * k + task.chunk];
                clock = self.transcode_round(round, q, step.step, clock, &mut out)?;
                out.round_ends.push(clock);
            }
            task_end[ti] = clock;
            out.total_slots = out.total_slots.max(clock);
        }
        // with any lane incomplete, every base round still streams (just
        // with fewer chunk sub-rounds), so the latency-bearing count is
        // unchanged
        out.h2h_rounds = plan.steps.iter().map(|s| s.base_rounds()).sum();
        Ok(out)
    }

    /// Transcode one synchronous round starting at `start`; returns the
    /// round's completion slot.
    fn transcode_round(
        &mut self,
        round: &Round,
        q: usize,
        step: Option<crate::collectives::subgroups::Step>,
        start: u64,
        sched: &mut Schedule,
    ) -> Result<u64> {
        let mut end = start;
        for t in &round.transfers {
            let done = self.place_transfer(t.src, &t.dsts, t.bytes, q, step, start, &mut |i| {
                sched.instructions.push(i)
            })?;
            end = end.max(done);
        }
        Ok(end)
    }

    /// Place one transfer against the occupancy state: stripe it across
    /// its transceiver groups, find each stripe's earliest
    /// contention-free slot ≥ `start`, record the occupancy, and emit
    /// one [`NicInstruction`] per non-empty stripe. Returns the
    /// transfer's completion slot. This is the single placement routine
    /// behind both the eager round paths and the shard-streaming
    /// [`transcode_stream`], so the two can never drift.
    fn place_transfer(
        &mut self,
        src: NodeCoord,
        dsts: &[NodeCoord],
        bytes: u64,
        q: usize,
        step: Option<crate::collectives::subgroups::Step>,
        start: u64,
        emit: &mut dyn FnMut(NicInstruction),
    ) -> Result<u64> {
        let p = self.p;
        let mut end = start;
        ensure!(!dsts.is_empty(), "transfer without destinations");
        ensure!(dsts.iter().all(|d| *d != src), "self-transfer from {}", src);
        // a multicast shares one wavelength: all dsts must be tuned to
        // the same channel and live in the same destination group
        let w = rx_wavelength(dsts[0]);
        let dg = dsts[0].g;
        ensure!(
            dsts.iter().all(|d| rx_wavelength(*d) == w && d.g == dg),
            "multicast destinations must share wavelength and group"
        );
        let dense = step == Some(crate::collectives::subgroups::Step::S4)
            && p.subnet_kind == crate::topology::ramp::SubnetKind::RouteSelect;
        let groups = trx_groups_from_base(p, base_trx_for(p, step, src, dsts[0]), q, dense);
        let stripes = split_bytes(bytes, groups.len() as u64);
        for (trx, bytes) in groups.iter().zip(stripes) {
            if bytes == 0 {
                continue;
            }
            let n_slots = bytes.div_ceil(group_slot_payload(p)).max(1);
            let subnet = SubnetId {
                src_group: src.g,
                dst_group: dg,
                trx: *trx,
            };
            // earliest slot ≥ start where the subnet wavelength space,
            // the transmitter and every receiver are free
            let mut slot = start;
            slot = slot.max(*self.tx_free.get(&(src.flat(p), *trx)).unwrap_or(&0));
            for d in dsts {
                let (in_k, out_k) = rack_keys(p, src, d.j);
                slot = slot.max(*self.subnet_in_free.get(&(subnet, w, in_k)).unwrap_or(&0));
                slot = slot.max(*self.subnet_out_free.get(&(subnet, w, out_k)).unwrap_or(&0));
                slot = slot.max(*self.rx_free.get(&(d.flat(p), *trx)).unwrap_or(&0));
            }
            let done = slot + n_slots;
            self.tx_free.insert((src.flat(p), *trx), done);
            for d in dsts {
                let (in_k, out_k) = rack_keys(p, src, d.j);
                self.subnet_in_free.insert((subnet, w, in_k), done);
                self.subnet_out_free.insert((subnet, w, out_k), done);
                self.rx_free.insert((d.flat(p), *trx), done);
            }
            end = end.max(done);
            emit(NicInstruction {
                src,
                dsts: dsts.to_vec(),
                trx: *trx,
                subnet,
                wavelength: w,
                slot,
                n_slots,
                bytes,
            });
        }
        Ok(end)
    }

    /// Drop all recorded occupancy, keeping map capacity. The
    /// shard-streaming path calls this per (round, shard): all frees
    /// recorded in earlier rounds are ≤ the current round's start slot
    /// (rounds are synchronous), and within a round distinct shards
    /// touch disjoint transmitters, receivers and (subnet, λ, rack)
    /// keys (the co-designed schedule-less property), so clearing
    /// changes no placement — asserted instruction-for-instruction by
    /// the differential stream tests.
    fn clear_occupancy(&mut self) {
        self.subnet_in_free.clear();
        self.subnet_out_free.clear();
        self.tx_free.clear();
        self.rx_free.clear();
    }
}

/// Split `bytes` as evenly as possible into `n` stripes.
fn split_bytes(bytes: u64, n: u64) -> Vec<u64> {
    let base = bytes / n;
    let rem = bytes % n;
    (0..n).map(|i| base + u64::from(i < rem)).collect()
}

/// Convenience: transcode a plan with a fresh transcoder.
pub fn transcode_plan(p: &RampParams, plan: &CollectivePlan) -> Result<Schedule> {
    Transcoder::new(p).transcode(plan)
}

/// Convenience: derive the plan's cross-step lane schedule and transcode
/// through it with a fresh transcoder.
pub fn transcode_plan_lanes(p: &RampParams, plan: &CollectivePlan) -> Result<Schedule> {
    let sched = lanes::LaneSchedule::from_plan(plan);
    Transcoder::new(p).transcode_lanes(plan, &sched)
}

/// Convenience: partial (resume) lane transcode with a fresh transcoder —
/// chunks flagged in `skip` send nothing (see
/// [`Transcoder::transcode_lanes_partial`]).
pub fn transcode_plan_lanes_partial(
    p: &RampParams,
    plan: &CollectivePlan,
    skip: &[bool],
) -> Result<Schedule> {
    let sched = lanes::LaneSchedule::from_plan(plan);
    Transcoder::new(p).transcode_lanes_partial(plan, &sched, skip)
}

/// The folded accounting of a streamed transcode: everything the
/// estimator and the conservation checks need from a schedule, with no
/// instruction list behind it. Field-for-field comparable with an eager
/// [`Schedule`] of the same plan.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScheduleSummary {
    /// NIC instructions emitted (one per non-empty stripe).
    pub n_instructions: u64,
    /// Total bytes on the wire (stripe bytes sum exactly to transfer
    /// bytes, so this equals the plan's `total_wire_bytes`).
    pub total_bytes: u64,
    /// Total timeslots from first transmission to completion.
    pub total_slots: u64,
    /// Latency-bearing round count (chunk sub-rounds share one H2H).
    pub h2h_rounds: usize,
    /// Synchronous wire rounds (chunk sub-rounds counted individually).
    pub n_rounds: usize,
}

/// Transcode a streamed plan one rank-shard at a time, folding slot,
/// round and byte totals without materializing rounds, transfers or the
/// instruction list. Peak memory is O(shard): one subgroup's
/// coordinates plus that shard's occupancy entries, independent of N.
///
/// Every instruction still flows through `visit` in the exact order the
/// eager [`Transcoder::transcode`] would push it (rounds are
/// group-major, and [`crate::collectives::stream::shards`] yields
/// subgroups in `subgroup_list` order), so callers can stream
/// instructions to a sink — or pass `|_| {}` for accounting only.
pub fn transcode_stream(
    p: &RampParams,
    plan: &crate::collectives::stream::StreamPlan,
    mut visit: impl FnMut(NicInstruction),
) -> Result<ScheduleSummary> {
    let mut tc = Transcoder::new(p);
    let mut sum = ScheduleSummary::default();
    let mut clock = 0u64;
    for st in &plan.steps {
        let q = st.trx_q.max(1);
        sum.h2h_rounds += st.base_rounds();
        for pairs in st.pair_rounds() {
            for view in &st.views {
                let bytes = view.bytes();
                let start = clock;
                let mut end = start;
                for shard in crate::collectives::stream::shards(p, st.step) {
                    // exact despite the per-shard reset: see
                    // `Transcoder::clear_occupancy`
                    tc.clear_occupancy();
                    for &(from, to) in &pairs {
                        let done = tc.place_transfer(
                            shard[from],
                            &[shard[to]],
                            bytes,
                            q,
                            Some(st.step),
                            start,
                            &mut |ins| {
                                sum.n_instructions += 1;
                                sum.total_bytes += ins.bytes;
                                visit(ins);
                            },
                        )?;
                        end = end.max(done);
                    }
                }
                clock = end;
                sum.n_rounds += 1;
            }
        }
    }
    sum.total_slots = clock;
    Ok(sum)
}

/// Effective number of stripes a transfer of a given plan step gets.
pub fn effective_stripes(
    p: &RampParams,
    step: Option<crate::collectives::subgroups::Step>,
    q: usize,
) -> u64 {
    let dense = step == Some(crate::collectives::subgroups::Step::S4)
        && p.subnet_kind == crate::topology::ramp::SubnetKind::RouteSelect;
    if dense {
        q.max(1).min(p.x) as u64
    } else {
        q.max(1).min((p.x / p.j).max(1)) as u64
    }
}

/// Verify the paper's **schedule-less** property for a plan: the makespan
/// of each round equals the slots of its largest single transfer — i.e.
/// the deterministic assignment never had to serialize anything.
pub fn is_contention_free(p: &RampParams, plan: &CollectivePlan) -> Result<bool> {
    let sched = transcode_plan(p, plan)?;
    let mut prev_end = 0u64;
    let mut i = 0usize;
    for step in &plan.steps {
        let q = effective_stripes(p, step.step, step.trx_q);
        for round in &step.rounds {
            let round_end = sched.round_ends[i];
            i += 1;
            let biggest = round.max_transfer_bytes();
            if biggest == 0 {
                prev_end = round_end;
                continue;
            }
            let per_stripe = biggest.div_ceil(q);
            let ideal = per_stripe.div_ceil(group_slot_payload(p)).max(1);
            if round_end - prev_end > ideal {
                return Ok(false);
            }
            prev_end = round_end;
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ramp_x::RampX;
    use crate::collectives::MpiOp;
    use crate::rng::Xoshiro256;

    fn random_inputs(n: usize, c: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut r = Xoshiro256::seed_from(seed);
        (0..n)
            .map(|_| (0..c).map(|_| r.next_f32()).collect())
            .collect()
    }

    fn check_no_double_booking(p: &RampParams, s: &Schedule) {
        // the fabric simulator is the subnet-kind-aware referee
        let report = crate::simulator::OpticalFabric::new(p.clone()).execute(s);
        assert!(report.ok(), "physical violations: {:?}", report.violations);
    }

    #[test]
    fn eq2_trx_selection() {
        let p = RampParams::fig8_example();
        let a = NodeCoord::new(1, 2, 3);
        let b = NodeCoord::new(2, 0, 5);
        assert_eq!(base_trx(&p, a, b), (1 + 2 + 2) % 3);
        // q clamped by x/J = 1 at J = x
        assert_eq!(trx_groups(&p, a, b, 5), vec![(1 + 2 + 2) % 3]);
        // J < x frees offsets in multiples of J
        let p2 = RampParams::new(8, 2, 16, 1);
        let a2 = NodeCoord::new(0, 1, 0);
        let b2 = NodeCoord::new(3, 0, 7);
        assert_eq!(trx_groups(&p2, a2, b2, 3), vec![4, 6, 0]);
    }

    #[test]
    fn every_ramp_x_plan_is_contention_free() {
        // The headline §6 claim, checked mechanically per-op.
        for p in [
            RampParams::new(2, 2, 4, 1),
            RampParams::fig8_example(),
            RampParams::new(4, 2, 4, 1),
            RampParams::new(2, 2, 8, 1), // DG=4 multi-round step 4
            RampParams::new(4, 4, 8, 1), // even x with J = x (step-3 aliasing regression)
            RampParams::new(4, 4, 4, 1), // DG=1
        ] {
            let n = p.n_nodes();
            for op in MpiOp::all() {
                let elems = match op {
                    MpiOp::AllGather | MpiOp::Gather { .. } => 4,
                    _ => 2 * n,
                };
                let mut bufs = random_inputs(n, elems, 42);
                let plan = RampX::new(&p).run(op, &mut bufs).unwrap();
                let sched = transcode_plan(&p, &plan).unwrap();
                check_no_double_booking(&p, &sched);
                assert!(
                    is_contention_free(&p, &plan).unwrap(),
                    "{} serialized on {p:?}",
                    op.name()
                );
            }
        }
    }

    #[test]
    fn chunked_plans_stay_contention_free_and_amortize_h2h() {
        use crate::collectives::arena::Pipeline;
        for p in [RampParams::fig8_example(), RampParams::new(2, 2, 8, 1)] {
            let n = p.n_nodes();
            for op in MpiOp::all() {
                let elems = match op {
                    MpiOp::AllGather | MpiOp::Gather { .. } => 6,
                    _ => 2 * n,
                };
                let mut serial_bufs = random_inputs(n, elems, 17);
                let serial = RampX::new(&p).run(op, &mut serial_bufs).unwrap();
                let serial_sched = transcode_plan(&p, &serial).unwrap();
                let mut bufs = random_inputs(n, elems, 17);
                let plan = RampX::new(&p)
                    .with_pipeline(Pipeline::fixed(3))
                    .run(op, &mut bufs)
                    .unwrap();
                let sched = transcode_plan(&p, &plan).unwrap();
                check_no_double_booking(&p, &sched);
                // every chunk sub-round is itself schedule-less
                assert!(
                    is_contention_free(&p, &plan).unwrap(),
                    "chunked {} serialized on {p:?}",
                    op.name()
                );
                // chunking adds wire rounds but no latency-bearing ones
                assert_eq!(
                    sched.h2h_rounds,
                    serial_sched.h2h_rounds,
                    "chunked {} pays extra H2H on {p:?}",
                    op.name()
                );
                assert_eq!(serial_sched.h2h_rounds, serial_sched.round_ends.len());
                assert!(sched.round_ends.len() >= sched.h2h_rounds);
            }
        }
    }

    #[test]
    fn lane_transcode_overlaps_steps_and_stays_clean() {
        use crate::collectives::arena::Pipeline;
        for p in [RampParams::fig8_example(), RampParams::new(2, 2, 8, 1)] {
            let n = p.n_nodes();
            for op in [
                MpiOp::ReduceScatter,
                MpiOp::AllGather,
                MpiOp::AllReduce,
                MpiOp::AllToAll,
                MpiOp::Scatter { root: 2 },
                MpiOp::Gather { root: 1 },
                MpiOp::Reduce { root: 0 },
            ] {
                let elems = match op {
                    MpiOp::AllGather | MpiOp::Gather { .. } => 6,
                    _ => 2 * n,
                };
                let mut bufs = random_inputs(n, elems, 29);
                let plan = crate::collectives::ramp_x::RampX::new(&p)
                    .with_pipeline(Pipeline::cross(3))
                    .run(op, &mut bufs)
                    .unwrap();
                let step_major = transcode_plan(&p, &plan).unwrap();
                let laned = transcode_plan_lanes(&p, &plan).unwrap();
                // same physics: violation-free, same bytes, same H2H —
                // the interleaving changes *when*, never *what*
                check_no_double_booking(&p, &laned);
                let bytes = |s: &Schedule| s.instructions.iter().map(|i| i.bytes).sum::<u64>();
                assert_eq!(bytes(&laned), bytes(&step_major), "{}", op.name());
                assert_eq!(laned.h2h_rounds, step_major.h2h_rounds, "{}", op.name());
                assert_eq!(laned.round_ends.len(), step_major.round_ends.len());
                assert!(laned.total_slots > 0);
            }
        }
    }

    #[test]
    fn lane_transcode_overlap_win_on_disjoint_resources() {
        use crate::collectives::plan::{PlanStep, Transfer};
        // two lane-aligned steps, K = 2 chunks, whose transfers use
        // disjoint transmitters/subnets: step-major serializes all four
        // sub-rounds; the lane schedule releases (step 1, chunk 0) at the
        // end of (step 0, chunk 0), overlapping it with (step 0, chunk 1)
        // — one sub-round of wire time saved, deterministically.
        let p = RampParams::fig8_example();
        let bytes = group_slot_payload(&p) * 4; // 4 slots per sub-round
        let mk_step = |src: NodeCoord, dst: NodeCoord| PlanStep {
            rounds: (0..2)
                .map(|_| {
                    let mut r = Round::default();
                    r.transfers.push(Transfer::unicast(src, dst, bytes));
                    r
                })
                .collect(),
            n_chunks: 2,
            lane_aligned: true,
            trx_q: 1,
            ..Default::default()
        };
        let mut plan = CollectivePlan::default();
        plan.steps.push(mk_step(NodeCoord::new(0, 0, 0), NodeCoord::new(1, 0, 0)));
        plan.steps.push(mk_step(NodeCoord::new(2, 1, 1), NodeCoord::new(0, 2, 1)));
        let step_major = transcode_plan(&p, &plan).unwrap();
        assert_eq!(step_major.total_slots, 16, "4 serialized sub-rounds of 4 slots");
        let laned = transcode_plan_lanes(&p, &plan).unwrap();
        check_no_double_booking(&p, &laned);
        assert_eq!(
            laned.total_slots, 12,
            "cross-step lanes must overlap one sub-round per aligned boundary"
        );
        assert_eq!(laned.h2h_rounds, step_major.h2h_rounds);
    }

    #[test]
    fn partial_lane_transcode_conserves_bytes_against_the_chunk_split() {
        use crate::collectives::arena::Pipeline;
        use crate::fault::recovery::chunk_step_bytes;
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        for op in [MpiOp::ReduceScatter, MpiOp::AllGather, MpiOp::AllReduce, MpiOp::AllToAll] {
            let elems = match op {
                MpiOp::AllGather => 6,
                _ => 2 * n,
            };
            let mut bufs = random_inputs(n, elems, 31);
            let plan = RampX::new(&p)
                .with_pipeline(Pipeline::cross(3))
                .run(op, &mut bufs)
                .unwrap();
            let k = plan.steps[0].n_chunks.max(1);
            if k < 2 {
                continue;
            }
            let full = transcode_plan_lanes(&p, &plan).unwrap();
            let bytes = |s: &Schedule| s.instructions.iter().map(|i| i.bytes).sum::<u64>();
            let split = chunk_step_bytes(&plan, k).expect("uniformly chunked plan");
            // resume with chunk 0 done: the partial schedule must carry
            // exactly the full bytes minus chunk 0's share, and stay
            // physically clean on the fabric
            let mut skip = vec![false; k];
            skip[0] = true;
            let partial = transcode_plan_lanes_partial(&p, &plan, &skip).unwrap();
            check_no_double_booking(&p, &partial);
            let carried: u64 = split[0].iter().sum();
            assert_eq!(
                bytes(&partial) + carried,
                bytes(&full),
                "{}: resumed + carried bytes must conserve Table-8 totals",
                op.name()
            );
            assert!(bytes(&partial) < bytes(&full), "{}: resume must send less", op.name());
            assert_eq!(partial.h2h_rounds, full.h2h_rounds, "{}", op.name());
            // all chunks done → nothing to send at all
            let none = transcode_plan_lanes_partial(&p, &plan, &vec![true; k]).unwrap();
            assert_eq!(bytes(&none), 0, "{}", op.name());
        }
    }

    #[test]
    fn lane_transcode_of_unchunked_plan_matches_step_major() {
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        let mut bufs = random_inputs(n, 2 * n, 30);
        let plan = RampX::new(&p).run(MpiOp::AllReduce, &mut bufs).unwrap();
        let a = transcode_plan(&p, &plan).unwrap();
        let b = transcode_plan_lanes(&p, &plan).unwrap();
        // every boundary is a barrier, so the schedules coincide
        assert_eq!(a.total_slots, b.total_slots);
        assert_eq!(a.h2h_rounds, b.h2h_rounds);
        check_no_double_booking(&p, &b);
    }

    #[test]
    fn slot_counts_follow_payload() {
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        // big message: slots per round = ceil(bytes / 950·b)
        let elems = 4096 * n;
        let mut bufs = random_inputs(n, elems, 7);
        let plan = RampX::new(&p).run(MpiOp::ReduceScatter, &mut bufs).unwrap();
        let sched = transcode_plan(&p, &plan).unwrap();
        let payload = group_slot_payload(&p);
        let mut expect = 0u64;
        for step in &plan.steps {
            let q = effective_stripes(&p, step.step, step.trx_q);
            for round in &step.rounds {
                expect += round
                    .max_transfer_bytes()
                    .div_ceil(q)
                    .div_ceil(payload)
                    .max(1);
            }
        }
        assert_eq!(sched.total_slots, expect);
    }

    #[test]
    fn wire_time_reflects_slots() {
        let p = RampParams::fig8_example();
        let mut bufs = random_inputs(p.n_nodes(), p.n_nodes(), 3);
        let plan = RampX::new(&p).run(MpiOp::AllReduce, &mut bufs).unwrap();
        let sched = transcode_plan(&p, &plan).unwrap();
        assert!((sched.wire_time(&p) - sched.total_slots as f64 * p.slot_time).abs() < 1e-15);
        assert!(sched.total_slots > 0);
    }

    #[test]
    fn rejects_mixed_wavelength_multicast() {
        use crate::collectives::plan::{CollectivePlan, PlanStep, Round, Transfer};
        let p = RampParams::fig8_example();
        let mut plan = CollectivePlan::default();
        let mut st = PlanStep::default();
        let mut r = Round::default();
        r.transfers.push(Transfer {
            src: NodeCoord::new(0, 0, 0),
            dsts: vec![NodeCoord::new(1, 0, 1), NodeCoord::new(1, 0, 2)],
            bytes: 100,
        });
        st.rounds.push(r);
        plan.steps.push(st);
        assert!(transcode_plan(&p, &plan).is_err());
    }

    #[test]
    fn serialization_detected_when_forced() {
        // Two same-subnet same-wavelength transfers in one round must
        // serialize — is_contention_free reports it.
        use crate::collectives::plan::{CollectivePlan, PlanStep, Round, Transfer};
        let p = RampParams::fig8_example();
        let mut plan = CollectivePlan::default();
        let mut st = PlanStep::default();
        let mut r = Round::default();
        // srcs (0,0,1) and (0,0,2): same rack ⇒ same base trx toward group
        // 1; both send to a λ=4 node ⇒ same subnet, same wavelength.
        r.transfers.push(Transfer::unicast(
            NodeCoord::new(0, 0, 1),
            NodeCoord::new(1, 0, 4),
            100,
        ));
        r.transfers.push(Transfer::unicast(
            NodeCoord::new(0, 0, 2),
            NodeCoord::new(1, 1, 4),
            100,
        ));
        st.rounds.push(r);
        plan.steps.push(st);
        assert!(!is_contention_free(&p, &plan).unwrap());
    }
}
