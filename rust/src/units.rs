//! Physical units and constants used throughout the models.
//!
//! Conventions: time in **seconds** (f64), bandwidth in **bits/second**,
//! message sizes in **bytes**, optical power in **dBm**, electrical power in
//! **watts**, cost in **USD**. Helper constructors keep call sites legible
//! (`400.0 * GBPS`, `1.3 * US`).

/// 1 gigabit per second, in bit/s.
pub const GBPS: f64 = 1e9;
/// 1 terabit per second, in bit/s.
pub const TBPS: f64 = 1e12;
/// 1 nanosecond, in seconds.
pub const NS: f64 = 1e-9;
/// 1 microsecond, in seconds.
pub const US: f64 = 1e-6;
/// 1 millisecond, in seconds.
pub const MS: f64 = 1e-3;
/// 1 kibibyte.
pub const KIB: u64 = 1 << 10;
/// 1 mebibyte.
pub const MIB: u64 = 1 << 20;
/// 1 gibibyte.
pub const GIB: u64 = 1 << 30;
/// Decimal megabyte (the paper's "MB" is decimal in message-size sweeps).
pub const MB: u64 = 1_000_000;
/// Decimal gigabyte.
pub const GB: u64 = 1_000_000_000;

/// Convert a per-second rate in bit/s and a size in bytes to seconds.
#[inline]
pub fn transfer_time(bytes: u64, bits_per_sec: f64) -> f64 {
    (bytes as f64 * 8.0) / bits_per_sec
}

/// dBm -> milliwatts.
#[inline]
pub fn dbm_to_mw(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0)
}

/// milliwatts -> dBm.
#[inline]
pub fn mw_to_dbm(mw: f64) -> f64 {
    10.0 * mw.log10()
}

/// Pretty-print seconds with an adaptive unit (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    let a = secs.abs();
    if a < 1e-6 {
        format!("{:.2} ns", secs * 1e9)
    } else if a < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if a < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else if a < 120.0 {
        format!("{:.3} s", secs)
    } else if a < 7200.0 {
        format!("{:.2} min", secs / 60.0)
    } else if a < 48.0 * 3600.0 {
        format!("{:.2} h", secs / 3600.0)
    } else {
        format!("{:.2} days", secs / 86400.0)
    }
}

/// Pretty-print a byte count (KiB/MiB/GiB adaptive).
pub fn fmt_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    if bytes < KIB {
        format!("{bytes} B")
    } else if bytes < MIB {
        format!("{:.1} KiB", b / KIB as f64)
    } else if bytes < GIB {
        format!("{:.1} MiB", b / MIB as f64)
    } else {
        format!("{:.2} GiB", b / GIB as f64)
    }
}

/// Pretty-print a bandwidth in bit/s (Gbps/Tbps adaptive).
pub fn fmt_bw(bps: f64) -> String {
    if bps < TBPS {
        format!("{:.1} Gbps", bps / GBPS)
    } else {
        format!("{:.2} Tbps", bps / TBPS)
    }
}

/// Pretty-print a large count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_basic() {
        // 1 GiB over 400 Gbps = 8 * 2^30 / 4e11 s ≈ 21.47 ms
        let t = transfer_time(GIB, 400.0 * GBPS);
        assert!((t - 0.02147).abs() < 1e-4, "{t}");
    }

    #[test]
    fn dbm_roundtrip() {
        for dbm in [-20.0, -3.0, 0.0, 10.0, 17.0] {
            let mw = dbm_to_mw(dbm);
            assert!((mw_to_dbm(mw) - dbm).abs() < 1e-9);
        }
        assert!((dbm_to_mw(0.0) - 1.0).abs() < 1e-12);
        assert!((dbm_to_mw(10.0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_time(5e-9), "5.00 ns");
        assert_eq!(fmt_time(2.5e-4), "250.00 µs");
        assert_eq!(fmt_time(0.0215), "21.500 ms");
        assert_eq!(fmt_bytes(1024), "1.0 KiB");
        assert_eq!(fmt_bw(400e9), "400.0 Gbps");
        assert_eq!(fmt_bw(12.8e12), "12.80 Tbps");
        assert_eq!(fmt_count(65536), "65,536");
        assert_eq!(fmt_count(1_234_567), "1,234,567");
    }
}
