//! Ablation studies over the design choices DESIGN.md calls out:
//! subnet implementation (B&S vs R&S), oversubscription σ, broadcast
//! pipelining (Eq 1), and the multi-transceiver striping of Eqs 3–5.
//! Each ablation asserts the *direction* of the effect — the reason the
//! paper made the choice.

use ramp::collectives::MpiOp;
use ramp::estimator::CollectiveEstimator;
use ramp::topology::ramp::RampParams;
use ramp::units::{GB, MB};

/// §3.1/§6.2.2: Route & Select subnets unlock the full-capacity pairwise
/// step 4; Broadcast & Select caps it at one transceiver group. The
/// all-to-all (step-4 heavy: m·x/Λ per peer) must get faster under R&S.
#[test]
fn ablation_subnet_kind_step4_capacity() {
    let rs = CollectiveEstimator::ramp(&RampParams::max_scale());
    let bs = CollectiveEstimator::ramp(&RampParams::max_scale().with_broadcast_select());
    let n = 65_536;
    let t_rs = rs.completion_time(MpiOp::AllToAll, GB, n).total();
    let t_bs = bs.completion_time(MpiOp::AllToAll, GB, n).total();
    assert!(
        t_bs / t_rs > 2.0,
        "R&S should win all-to-all clearly: B&S {t_bs} vs R&S {t_rs}"
    );
    // ops with tiny step-4 messages barely notice
    let rs_rs = rs.completion_time(MpiOp::ReduceScatter, GB, n).total();
    let bs_rs = bs.completion_time(MpiOp::ReduceScatter, GB, n).total();
    assert!(bs_rs / rs_rs < 1.5, "reduce-scatter is step-1 bound: {bs_rs} vs {rs_rs}");
}

/// §2.4/§8.2: oversubscription hurts the EPS baseline monotonically, and
/// all-to-all (constant message per step) more than reduce-scatter
/// (shrinking message per step).
#[test]
fn ablation_oversubscription_monotone() {
    let n = 65_536;
    let mut last_a2a = 0.0;
    for sigma in [1.0, 4.0, 12.0, 64.0] {
        let ft = CollectiveEstimator::fat_tree_hierarchical(sigma);
        let t = ft.completion_time(MpiOp::AllToAll, GB, n).total();
        assert!(t > last_a2a, "σ={sigma}: {t} not > {last_a2a}");
        last_a2a = t;
    }
    let matched = CollectiveEstimator::fat_tree_hierarchical(1.0);
    let over = CollectiveEstimator::fat_tree_hierarchical(64.0);
    let pen_a2a = over.completion_time(MpiOp::AllToAll, GB, n).total()
        / matched.completion_time(MpiOp::AllToAll, GB, n).total();
    let pen_rs = over.completion_time(MpiOp::ReduceScatter, GB, n).total()
        / matched.completion_time(MpiOp::ReduceScatter, GB, n).total();
    assert!(pen_a2a > pen_rs, "a2a penalty {pen_a2a} ≤ rs penalty {pen_rs}");
}

/// Eq 1: pipelining the SOA-multicast broadcast beats a single-chunk
/// tree for large messages (k ≈ sqrt(m·β/α) ≫ 1), and degenerates to
/// k = 1 for tiny ones.
#[test]
fn ablation_broadcast_pipelining() {
    use ramp::collectives::ops::broadcast_phases;
    let p = RampParams::max_scale();
    let small = broadcast_phases(&p, 10_000);
    assert_eq!(small[0].rounds, 2, "tiny message: k = 1, rounds = k + s - 2 = 2");
    let large = broadcast_phases(&p, 10 * GB);
    let k = large[0].rounds - 1;
    assert!(k > 20, "10 GB should pipeline into many chunks, got {k}");
    // pipelined completion ≈ m/BW + k·α ≪ serial tree's 2·m/BW for big m
    let est = CollectiveEstimator::ramp(&p);
    let t = est.completion_time(MpiOp::Broadcast { root: 0 }, 10 * GB, 65_536).total();
    let serial_two_hops = 2.0 * (10 * GB) as f64 * 8.0 / p.node_capacity();
    assert!(t < serial_two_hops, "pipelining lost: {t} vs {serial_two_hops}");
}

/// Eqs 3–5: jobs smaller than the fabric stripe across idle transceiver
/// groups, so per-peer bandwidth rises exactly as messages-per-peer grow
/// (q = ⌊x/(s−1)⌋): the H2T term is scale-invariant and only the
/// step-count (H2H) grows — an 8-node all-reduce needs 2 rounds, the
/// 65,536-node one needs 8+, at (nearly) the same wire time.
#[test]
fn ablation_job_striping() {
    let est = CollectiveEstimator::ramp(&RampParams::max_scale());
    let m = 100 * MB;
    let small = est.completion_time(MpiOp::AllReduce, m, 8);
    let full = est.completion_time(MpiOp::AllReduce, m, 65_536);
    // fewer steps ⇒ strictly less H2H and less total
    assert!(small.h2h < full.h2h * 0.5, "{} vs {}", small.h2h, full.h2h);
    assert!(small.total() < full.total());
    // …while the wire time stays within 20% (striping compensates the
    // smaller subgroup fan-out)
    assert!(
        (small.h2t / full.h2t - 1.0).abs() < 0.2,
        "striping should balance H2T: {} vs {}",
        small.h2t,
        full.h2t
    );
    assert!(est.n_steps(MpiOp::AllReduce, m, 8) < est.n_steps(MpiOp::AllReduce, m, 65_536));
}

/// Failure injection: corrupt a valid NIC schedule and confirm the
/// fabric referee catches each class of physical violation.
#[test]
fn ablation_fabric_catches_corruption() {
    use ramp::collectives::ramp_x::RampX;
    use ramp::rng::Xoshiro256;
    use ramp::simulator::OpticalFabric;
    use ramp::transcoder::transcode_plan;

    let p = RampParams::fig8_example();
    let n = p.n_nodes();
    let mut rng = Xoshiro256::seed_from(3);
    let mut bufs: Vec<Vec<f32>> =
        (0..n).map(|_| (0..2 * n).map(|_| rng.next_f32()).collect()).collect();
    let plan = RampX::new(&p).run(MpiOp::AllReduce, &mut bufs).unwrap();
    let clean = transcode_plan(&p, &plan).unwrap();
    let fabric = OpticalFabric::new(p.clone());
    assert!(fabric.execute(&clean).ok());

    // (a) wavelength corruption → filter mismatch
    let mut bad = clean.clone();
    bad.instructions[0].wavelength = (bad.instructions[0].wavelength + 1) % p.lambda;
    assert!(!fabric.execute(&bad).ok(), "wavelength corruption undetected");

    // (b) slot collision → double booking
    let mut bad = clean.clone();
    let slot0 = bad.instructions[0].slot;
    // force a later same-resource instruction onto the same slot by
    // cloning instruction 0 verbatim
    let dup = bad.instructions[0].clone();
    bad.instructions.push(dup);
    let _ = slot0;
    assert!(!fabric.execute(&bad).ok(), "slot collision undetected");

    // (c) payload overrun
    let mut bad = clean;
    bad.instructions[0].bytes = u32::MAX as u64;
    assert!(!fabric.execute(&bad).ok(), "payload overrun undetected");
}
