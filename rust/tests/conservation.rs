//! Byte-conservation properties: the closed-form phase lists (Table 8),
//! the job-placement phase lists, the transfer-level plans the executors
//! emit, and the bytes the fabric actually carries must all agree.
//!
//! * `node_tx_bytes(ramp_phases(..))` == `node_tx_bytes(job_phases(.., N))`
//!   for every operation — the estimator's two entry points price the
//!   full network identically;
//! * the fabric's `wire_bytes` for an executed plan equals the closed
//!   form (exactly for the divisible message sizes used here; the padding
//!   in `div_ceil` is the only slack the closed form carries).

use ramp::collectives::ops::{job_phases, node_tx_bytes, ramp_phases};
use ramp::collectives::ramp_x::RampX;
use ramp::collectives::MpiOp;
use ramp::rng::Xoshiro256;
use ramp::simulator::OpticalFabric;
use ramp::topology::ramp::RampParams;
use ramp::transcoder::transcode_plan;

fn fabrics() -> Vec<RampParams> {
    vec![
        RampParams::new(2, 2, 4, 1),  // N=16, DG=2
        RampParams::fig8_example(),   // N=54, all four steps active
        RampParams::new(4, 2, 4, 1),  // N=32, step 4 inactive
        RampParams::new(2, 2, 8, 1),  // N=32, DG=4 (multi-round step 4)
    ]
}

fn random_inputs(n: usize, elems: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut r = Xoshiro256::seed_from(seed);
    (0..n).map(|_| (0..elems).map(|_| r.next_f32()).collect()).collect()
}

#[test]
fn ramp_and_job_phases_agree_at_full_network() {
    for p in fabrics() {
        let n = p.n_nodes();
        for op in MpiOp::all() {
            for m in [4 * n as u64, 4096 * n as u64] {
                assert_eq!(
                    node_tx_bytes(&ramp_phases(&p, op, m)),
                    node_tx_bytes(&job_phases(&p, op, m, n)),
                    "{} closed forms disagree at m={m} on {p:?}",
                    op.name()
                );
            }
        }
    }
}

/// Active step sizes in execution order for the forward (shrinking) ops.
fn active_sizes(p: &RampParams) -> Vec<u64> {
    ramp::collectives::subgroups::Step::active(p)
        .iter()
        .map(|s| s.size(p) as u64)
        .collect()
}

/// Total wire bytes of a RAMP-x gather of `contrib` bytes per node: at
/// each step every holder except the per-subgroup sink forwards its whole
/// holding (holder subgroups are all-or-none by the §5 digit invariance).
fn gather_wire(p: &RampParams, contrib: u64) -> u64 {
    let mut holders = p.n_nodes() as u64;
    let mut hold = contrib;
    let mut wire = 0;
    for s in active_sizes(p) {
        let sinks = holders / s;
        wire += (holders - sinks) * hold;
        holders = sinks;
        hold *= s;
    }
    wire
}

/// Total wire bytes of a RAMP-x scatter of `m` bytes at the root: holders
/// multiply by `s` per step, each forwarding `(s−1)/s` of its holding.
fn scatter_wire(p: &RampParams, m: u64) -> u64 {
    let mut holders = 1u64;
    let mut hold = m;
    let mut wire = 0;
    for s in active_sizes(p) {
        let per = hold / s;
        wire += holders * per * (s - 1);
        holders *= s;
        hold = per;
    }
    wire
}

/// Expected fabric wire bytes for `op` with `m` message bytes (per-node
/// contribution bytes for all-gather/gather), matching the executors'
/// data movement exactly for N-divisible sizes.
fn expected_wire(p: &RampParams, op: MpiOp, m: u64) -> u64 {
    let n = p.n_nodes() as u64;
    match op {
        // symmetric: every node transmits the closed-form per-node total
        MpiOp::ReduceScatter | MpiOp::AllGather | MpiOp::AllReduce | MpiOp::AllToAll => {
            n * node_tx_bytes(&ramp_phases(p, op, m))
        }
        MpiOp::Scatter { .. } => scatter_wire(p, m),
        MpiOp::Gather { .. } => gather_wire(p, m),
        MpiOp::Reduce { .. } => {
            n * node_tx_bytes(&ramp_phases(p, MpiOp::ReduceScatter, m))
                + gather_wire(p, m / n)
        }
        // the executor models the barrier as an N-flag all-reduce
        MpiOp::Barrier => n * node_tx_bytes(&ramp_phases(p, MpiOp::AllReduce, 4 * n)),
        MpiOp::Broadcast { .. } => {
            // mirror the executor's Eq-1 pipeline: k chunks from the root
            // (x multicasts each, one fewer when the root is alone on its
            // wavelength in its group) + k chunks from each of the Λ−1
            // relay wavelengths into all x groups
            let s = 3.0;
            let alpha = p.propagation + p.io_latency;
            let beta = 1.0 / p.node_capacity();
            let k = (((m as f64 * 8.0 * (s - 2.0) * beta) / alpha).sqrt().round() as u64).max(1);
            let chunk = m.div_ceil(k);
            let root_txs = if p.j == 1 { p.x as u64 - 1 } else { p.x as u64 };
            chunk * k * (root_txs + (p.lambda as u64 - 1) * p.x as u64)
        }
    }
}

#[test]
fn executed_plans_conserve_bytes() {
    // the closed forms must tie to the executed wire bytes with chunk
    // pipelining off AND on — intra-step and cross-step: chunk sub-rounds
    // partition each base round's payload exactly (contiguously for the
    // base-round-major path, fraction-strided for the lane path), so the
    // totals are K- and schedule-invariant
    let pipelines = [
        ramp::collectives::arena::Pipeline::off(),
        ramp::collectives::arena::Pipeline::fixed(3),
        ramp::collectives::arena::Pipeline::auto(),
        ramp::collectives::arena::Pipeline::cross(3),
        ramp::collectives::arena::Pipeline::cross(0),
    ];
    for p in fabrics() {
        let n = p.n_nodes();
        let fabric = OpticalFabric::new(p.clone());
        for op in MpiOp::all() {
            for pipeline in pipelines {
                // 2N elements per node: divisible by every step-size
                // product, so the closed form's div_ceil padding slack is
                // zero
                let elems = 2 * n;
                let mut bufs = random_inputs(n, elems, 7);
                let plan =
                    RampX::new(&p).with_pipeline(pipeline).run(op, &mut bufs).unwrap();
                let sched = transcode_plan(&p, &plan).unwrap();
                let report = fabric.execute(&sched);
                assert!(
                    report.ok(),
                    "{} violations under {pipeline:?} on {p:?}: {:?}",
                    op.name(),
                    report.violations
                );

                let m = (elems * 4) as u64;
                let expect = expected_wire(&p, op, m);
                if matches!(op, MpiOp::Broadcast { .. }) {
                    // the pipeline chunk count is derived through f64 —
                    // allow a little slack against rounding differences
                    let diff = report.wire_bytes.abs_diff(expect);
                    assert!(
                        diff * 20 <= expect,
                        "broadcast wire {} vs closed form {} on {p:?}",
                        report.wire_bytes,
                        expect
                    );
                } else {
                    assert_eq!(
                        report.wire_bytes, expect,
                        "{} wire bytes diverge from closed form under {pipeline:?} on {p:?}",
                        op.name()
                    );
                }
                // the plan's own accounting must match the fabric's
                assert_eq!(report.wire_bytes, plan.total_wire_bytes(), "{}", op.name());
            }
        }
    }
}

#[test]
fn job_phases_cover_partial_jobs_conservatively() {
    // at job scale the closed form must still conserve per-node volume:
    // reduce-scatter moves ≥ (n−1)/n of the message, all-gather grows the
    // contribution to ≤ padding slack beyond m·n
    for p in fabrics() {
        let full = p.n_nodes();
        for n in [2usize, 3, full / 2, full - 1] {
            if n < 2 {
                continue;
            }
            let m = 4096u64 * full as u64;
            let sizes_prod: u64 = ramp::collectives::ops::job_step_sizes(&p, n)
                .iter()
                .map(|&s| s as u64)
                .product();
            // reduce-scatter telescopes to m − m/Πs (≥, with ceil padding)
            let rs = node_tx_bytes(&job_phases(&p, MpiOp::ReduceScatter, m, n));
            assert!(rs >= m - m / sizes_prod, "rs undercounts: {rs} for n={n} on {p:?}");
            // all-gather never divides, so it telescopes exactly
            let ag = node_tx_bytes(&job_phases(&p, MpiOp::AllGather, m, n));
            assert_eq!(ag, m * (sizes_prod - 1), "ag volume for n={n} on {p:?}");
        }
    }
}
