//! Integration property tests: the paper's §6 "schedule-less and
//! contention-less" claim, checked mechanically over randomized
//! parameters, operations, roots and message sizes — every plan the MPI
//! Engine emits must transcode with zero serialization and execute on
//! the fabric with zero physical violations.

use ramp::collectives::ramp_x::{padded_len, RampX};
use ramp::collectives::reference as oracle;
use ramp::collectives::MpiOp;
use ramp::rng::Xoshiro256;
use ramp::simulator::OpticalFabric;
use ramp::testutil::prop;
use ramp::topology::ramp::RampParams;
use ramp::transcoder::{is_contention_free, transcode_plan};

fn fabrics() -> Vec<RampParams> {
    vec![
        RampParams::new(2, 1, 2, 1),
        RampParams::new(2, 2, 4, 1),
        RampParams::fig8_example(),
        RampParams::new(4, 2, 4, 1),
        RampParams::new(2, 2, 8, 1),
        RampParams::new(4, 4, 8, 1),
        RampParams::new(4, 4, 8, 2), // b = 2 planes
        RampParams::new(5, 3, 10, 1), // odd x, J < x
    ]
}

fn random_bufs(rng: &mut Xoshiro256, n: usize, elems: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..elems).map(|_| (rng.next_below(2000) as f32) - 1000.0).collect())
        .collect()
}

#[test]
fn randomized_ops_are_contention_free_and_correct() {
    let fabrics = fabrics();
    prop::check(60, 2024, |g| {
        let p = g.pick(&fabrics).clone();
        let n = p.n_nodes();
        let ops = MpiOp::all();
        let op = match *g.pick(&ops) {
            // randomize roots for rooted ops
            MpiOp::Scatter { .. } => MpiOp::Scatter { root: g.usize_in(0, n) },
            MpiOp::Gather { .. } => MpiOp::Gather { root: g.usize_in(0, n) },
            MpiOp::Reduce { .. } => MpiOp::Reduce { root: g.usize_in(0, n) },
            MpiOp::Broadcast { .. } => MpiOp::Broadcast { root: g.usize_in(0, n) },
            other => other,
        };
        let elems = match op {
            MpiOp::AllGather | MpiOp::Gather { .. } => g.usize_in(1, 16),
            _ => padded_len(&p, g.usize_in(1, 4 * n)),
        };
        let mut rng = Xoshiro256::seed_from(g.case as u64 * 31 + 5);
        let mut bufs = random_bufs(&mut rng, n, elems);
        let inputs = bufs.clone();

        let plan = RampX::new(&p).run(op, &mut bufs).expect("plan");

        // data correctness vs the naive oracle
        let expect = match op {
            MpiOp::ReduceScatter => oracle::reduce_scatter(&inputs),
            MpiOp::AllGather => oracle::all_gather(&inputs),
            MpiOp::AllReduce => oracle::all_reduce(&inputs),
            MpiOp::AllToAll => oracle::all_to_all(&inputs),
            MpiOp::Scatter { root } => oracle::scatter(&inputs, root),
            MpiOp::Gather { root } => oracle::gather(&inputs, root),
            MpiOp::Reduce { root } => oracle::reduce(&inputs, root),
            MpiOp::Broadcast { root } => oracle::broadcast(&inputs, root),
            MpiOp::Barrier => bufs.clone(), // no data contract
        };
        if !matches!(op, MpiOp::Barrier) {
            assert_eq!(bufs, expect, "{} data mismatch on {p:?}", op.name());
        }

        // schedule-less: no serialization beyond the ideal slot count
        assert!(
            is_contention_free(&p, &plan).expect("transcode"),
            "{} serialized on {p:?}",
            op.name()
        );

        // physical: zero violations on the fabric
        let sched = transcode_plan(&p, &plan).expect("schedule");
        let report = OpticalFabric::new(p.clone()).execute(&sched);
        assert!(
            report.ok(),
            "{} fabric violations on {p:?}: {:?}",
            op.name(),
            report.violations
        );
    });
}

#[test]
fn broadcast_select_fabrics_also_clean() {
    // the conservative B&S wavelength-sharing rules must also hold
    let fabrics: Vec<RampParams> =
        fabrics().into_iter().map(|p| p.with_broadcast_select()).collect();
    prop::check(30, 77, |g| {
        let p = g.pick(&fabrics).clone();
        let n = p.n_nodes();
        let mut rng = Xoshiro256::seed_from(g.case as u64);
        let mut bufs = random_bufs(&mut rng, n, padded_len(&p, 2 * n));
        let plan = RampX::new(&p).run(MpiOp::AllReduce, &mut bufs).unwrap();
        let sched = transcode_plan(&p, &plan).unwrap();
        let report = OpticalFabric::new(p.clone()).execute(&sched);
        assert!(report.ok(), "B&S violations on {p:?}: {:?}", report.violations);
        assert!(is_contention_free(&p, &plan).unwrap(), "B&S serialized on {p:?}");
    });
}

#[test]
fn composition_identities() {
    // gather(root) ∘ scatter(root) = identity on the root's buffer;
    // broadcast then reduce-scatter distributes N·x slices
    let p = RampParams::fig8_example();
    let n = p.n_nodes();
    let engine = RampX::new(&p);
    let mut rng = Xoshiro256::seed_from(9);

    let original: Vec<f32> = (0..n * 2).map(|_| rng.next_f32()).collect();
    let mut bufs: Vec<Vec<f32>> = vec![vec![]; n];
    bufs[5] = original.clone();
    for (i, b) in bufs.iter_mut().enumerate() {
        if i != 5 {
            *b = vec![0.0; n * 2];
        }
    }
    // scatter from rank 5 then gather back to rank 5
    engine.run(MpiOp::Scatter { root: 5 }, &mut bufs).unwrap();
    engine.run(MpiOp::Gather { root: 5 }, &mut bufs).unwrap();
    assert_eq!(bufs[5], original);

    // reduce == all_reduce at the root
    let inputs = random_bufs(&mut rng, n, n);
    let mut a = inputs.clone();
    let mut b = inputs.clone();
    engine.run(MpiOp::Reduce { root: 3 }, &mut a).unwrap();
    engine.run(MpiOp::AllReduce, &mut b).unwrap();
    assert_eq!(a[3], b[3]);
}
