//! Differential test harness: every RAMP-x executor — chunk-pipelined
//! and unpipelined — against the naive reference collectives, on
//! seeded-random inputs across a grid of (fabric shape, message size,
//! chunk count) including non-power-of-two and padding-edge sizes.
//!
//! Three layers of agreement are asserted per grid point:
//! 1. executor output vs `collectives::reference` oracle, elementwise
//!    within f32 reduction tolerance (movement-only ops must be exact);
//! 2. pipelined output vs unpipelined output, *bitwise* — sub-dividing a
//!    step's element range never reorders the float summation;
//! 3. pipelined plan wire bytes vs unpipelined plan wire bytes (chunk
//!    sub-round byte counts partition the base round exactly), and the
//!    transcoded schedule executes violation-free on the fabric.
//!
//! Plus property tests for the arena invariants the pipelined executors
//! lean on: `arena_capacity` covers every phase the closed forms predict,
//! and chunked back-half writes never alias the front half or leak
//! across `ArenaRegion` boundaries.
//!
//! The grid also carries an **execution-substrate axis**: every chunked
//! configuration runs both on the PR-2 spawn-per-step scoped fallback
//! (`PoolSel::Off`) and on a shared persistent `WorkerPool` (forced, so
//! tiny payloads exercise the pooled path too), asserting bitwise
//! agreement with the scoped serial anchor and — at the end of the run —
//! that the pool never spawned a thread after construction.

use ramp::collectives::arena::{arena_capacity, BufferArena, Pipeline};
use ramp::collectives::lane_exec::LaneDriver;
use ramp::collectives::ops::{job_phases, job_step_sizes, ramp_phases};
use ramp::collectives::pool::{PoolSel, WorkerPool};
use ramp::collectives::ramp_x::{padded_len, RampX};
use ramp::collectives::{reference, MpiOp};
use ramp::rng::Xoshiro256;
use ramp::simulator::OpticalFabric;
use ramp::topology::ramp::RampParams;
use ramp::transcoder::transcode_plan;
use std::sync::{Arc, OnceLock};

/// Fabric shapes under differential test: all four steps active, steps 3
/// and 4 inactive, non-power-of-two node counts, multi-round step 4.
fn fabrics() -> Vec<RampParams> {
    vec![
        RampParams::new(2, 2, 4, 1),  // N=16, DG=2
        RampParams::fig8_example(),   // N=54 (non-pow2), all steps active
        RampParams::new(4, 2, 4, 1),  // N=32, step 4 inactive
        RampParams::new(3, 1, 3, 1),  // N=9 (non-pow2), steps 3+4 inactive
        RampParams::new(2, 2, 8, 1),  // N=32, DG=4 (multi-round step 4)
    ]
}

/// One persistent pool shared by the whole differential run — the same
/// lifetime shape the coordinator uses (threads created once, reused by
/// every collective under test).
fn shared_pool() -> Arc<WorkerPool> {
    static POOL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
    POOL.get_or_init(|| Arc::new(WorkerPool::new(3))).clone()
}

/// Execution-substrate axis of the grid: the PR-2 spawn-per-step scoped
/// fallback, and the persistent pool (forced, so even the tiny
/// differential payloads exercise the pooled path).
fn pool_modes() -> Vec<(&'static str, PoolSel)> {
    vec![("scoped", PoolSel::Off), ("pooled", PoolSel::Forced(shared_pool()))]
}

/// Chunk-count axis of the grid: off, small fixed counts (forced even on
/// tiny messages), the hard cap, auto selection, and the cross-step
/// chunk-lane modes (auto and forced chunk counts).
fn pipelines() -> Vec<Pipeline> {
    vec![
        Pipeline::off(),
        Pipeline::fixed(2),
        Pipeline::fixed(3),
        Pipeline::fixed(16),
        Pipeline::auto(),
        Pipeline::cross(0),
        Pipeline::cross(3),
    ]
}

/// Per-node message lengths (elements) for ops that require `N | m`:
/// the minimum, the padding edge just above it (`padded_len(n+1) = 2n`),
/// and non-power-of-two multiples.
fn divisible_sizes(p: &RampParams) -> Vec<usize> {
    let n = p.n_nodes();
    vec![n, padded_len(p, n + 1), 3 * n, 7 * n]
}

/// Per-node contribution lengths for all-gather/gather (no divisibility
/// constraint): including 1 and non-powers of two.
fn contribution_sizes() -> Vec<usize> {
    vec![1, 3, 8, 13]
}

fn random_inputs(n: usize, elems: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut r = Xoshiro256::seed_from(seed);
    (0..n)
        .map(|_| (0..elems).map(|_| (r.next_below(2000) as f32) * 0.25 - 250.0).collect())
        .collect()
}

/// Deterministic per-grid-point seed.
fn grid_seed(pi: usize, oi: usize, elems: usize, ki: usize) -> u64 {
    (pi as u64) << 48 ^ (oi as u64) << 32 ^ (elems as u64) << 8 ^ ki as u64
}

/// Elementwise comparison within f32 reduction tolerance. The executors
/// preserve the oracle's summation order, so `exact` ops must match
/// bitwise; reduce-carrying ops are allowed the tolerance the MPI
/// standard would.
fn assert_close(got: &[Vec<f32>], want: &[Vec<f32>], exact: bool, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: rank count");
    for (r, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.len(), w.len(), "{ctx}: rank {r} length");
        for (i, (a, b)) in g.iter().zip(w).enumerate() {
            if exact {
                assert!(a == b, "{ctx}: rank {r} elem {i}: {a} != {b}");
            } else {
                let tol = 1e-5 * b.abs().max(1.0);
                assert!((a - b).abs() <= tol, "{ctx}: rank {r} elem {i}: {a} vs {b}");
            }
        }
    }
}

fn oracle(op: MpiOp, inputs: &[Vec<f32>]) -> Option<Vec<Vec<f32>>> {
    Some(match op {
        MpiOp::ReduceScatter => reference::reduce_scatter(inputs),
        MpiOp::AllGather => reference::all_gather(inputs),
        MpiOp::AllReduce => reference::all_reduce(inputs),
        MpiOp::AllToAll => reference::all_to_all(inputs),
        MpiOp::Scatter { root } => reference::scatter(inputs, root),
        MpiOp::Gather { root } => reference::gather(inputs, root),
        MpiOp::Reduce { root } => reference::reduce(inputs, root),
        MpiOp::Broadcast { root } => reference::broadcast(inputs, root),
        MpiOp::Barrier => return None, // no buffer semantics to compare
    })
}

fn is_movement_only(op: MpiOp) -> bool {
    matches!(
        op,
        MpiOp::AllGather
            | MpiOp::AllToAll
            | MpiOp::Scatter { .. }
            | MpiOp::Gather { .. }
            | MpiOp::Broadcast { .. }
    )
}

/// Ops with a root, placed at interesting positions; symmetric ops once.
fn op_instances(n: usize) -> Vec<MpiOp> {
    let mut ops = vec![MpiOp::ReduceScatter, MpiOp::AllGather, MpiOp::AllReduce, MpiOp::AllToAll];
    for root in [0, n / 2, n - 1] {
        ops.push(MpiOp::Scatter { root });
        ops.push(MpiOp::Gather { root });
        ops.push(MpiOp::Reduce { root });
        ops.push(MpiOp::Broadcast { root });
    }
    ops.push(MpiOp::Barrier);
    ops
}

fn sizes_for(p: &RampParams, op: MpiOp) -> Vec<usize> {
    match op {
        MpiOp::AllGather | MpiOp::Gather { .. } => contribution_sizes(),
        MpiOp::Broadcast { .. } => vec![1, 64, 257],
        MpiOp::Barrier => vec![1],
        _ => divisible_sizes(p),
    }
}

#[test]
fn all_nine_ops_match_reference_pipelined_and_not() {
    for (pi, p) in fabrics().iter().enumerate() {
        let n = p.n_nodes();
        for (oi, &op) in op_instances(n).iter().enumerate() {
            for elems in sizes_for(p, op) {
                // unpipelined scoped run is the bitwise anchor for every
                // (chunking, execution substrate) combination
                let seed = grid_seed(pi, oi, elems, 0);
                let inputs = random_inputs(n, elems, seed);
                let mut serial = inputs.clone();
                RampX::new(p).with_pool(PoolSel::Off).run(op, &mut serial).unwrap();
                if let Some(expect) = oracle(op, &inputs) {
                    assert_close(
                        &serial,
                        &expect,
                        is_movement_only(op),
                        &format!("{} serial m={elems} on {p:?}", op.name()),
                    );
                }
                for (ki, pl) in pipelines().iter().enumerate() {
                    for (pool_name, pool) in pool_modes() {
                        if ki == 0 && pool_name == "scoped" {
                            continue; // that is the anchor itself
                        }
                        // lane-driver axis: cross-step configurations run
                        // both the event-driven and the in-order driver
                        let drivers: &[LaneDriver] = if pl.cross {
                            &[LaneDriver::Event, LaneDriver::InOrder]
                        } else {
                            &[LaneDriver::Event]
                        };
                        for &driver in drivers {
                            let mut chunked = inputs.clone();
                            RampX::new(p)
                                .with_pipeline(*pl)
                                .with_pool(pool.clone())
                                .with_lane_driver(driver)
                                .run(op, &mut chunked)
                                .unwrap();
                            assert_eq!(
                                serial,
                                chunked,
                                "{} K-grid point {ki} ({pool_name}, {driver:?}) diverged \
                                 bitwise at m={elems} on {p:?}",
                                op.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn barrier_counts_everyone_under_every_chunking() {
    for p in fabrics() {
        let n = p.n_nodes();
        for pl in pipelines() {
            for (pool_name, pool) in pool_modes() {
                let mut bufs = vec![vec![0.0f32]; n];
                RampX::new(&p)
                    .with_pipeline(pl)
                    .with_pool(pool)
                    .run(MpiOp::Barrier, &mut bufs)
                    .unwrap();
                assert!(
                    bufs.iter().all(|b| b[0] as usize == n),
                    "barrier under {pl:?} ({pool_name}) on {p:?}"
                );
            }
        }
    }
}

#[test]
fn persistent_pool_steady_state_spawns_nothing_across_the_net() {
    // run a slice of the nine-op net repeatedly on the shared pool: the
    // thread count must stay exactly as constructed — the warm-up spawn
    // is the only spawn there ever is
    let pool = shared_pool();
    assert_eq!(pool.spawn_count(), 3, "shared pool is constructed with 3 workers");
    let p = RampParams::fig8_example();
    let n = p.n_nodes();
    let x = RampX::new(&p)
        .with_pool(PoolSel::Forced(pool.clone()))
        .with_pipeline(Pipeline::fixed(3));
    let before = pool.fan_outs();
    for iter in 0..3 {
        for op in [MpiOp::AllReduce, MpiOp::AllToAll, MpiOp::Broadcast { root: 1 }] {
            let elems = 2 * n;
            let inputs = random_inputs(n, elems, 7 + iter);
            let mut got = inputs.clone();
            x.run(op, &mut got).unwrap();
            let mut want = inputs.clone();
            RampX::new(&p)
                .with_pool(PoolSel::Off)
                .with_pipeline(Pipeline::fixed(3))
                .run(op, &mut want)
                .unwrap();
            assert_eq!(got, want, "{} iteration {iter}", op.name());
        }
    }
    assert_eq!(pool.spawn_count(), 3, "steady-state collectives must spawn nothing");
    assert!(pool.fan_outs() > before, "the pooled path must actually dispatch");
    assert!(pool.sticky_hits() > 0, "repeat steps must hit the sticky map");
}

#[test]
fn pipelined_plans_execute_clean_and_conserve_wire_bytes() {
    for p in fabrics() {
        let n = p.n_nodes();
        let fabric = OpticalFabric::new(p.clone());
        for op in op_instances(n) {
            let elems = match op {
                MpiOp::AllGather | MpiOp::Gather { .. } => 6,
                MpiOp::Broadcast { .. } | MpiOp::Barrier => 8,
                _ => 2 * n,
            };
            let mut serial_bufs = random_inputs(n, elems, 99);
            let serial = RampX::new(&p).run(op, &mut serial_bufs).unwrap();
            for pl in [Pipeline::fixed(2), Pipeline::fixed(5), Pipeline::auto(), Pipeline::cross(3)]
            {
                let mut bufs = random_inputs(n, elems, 99);
                let plan = RampX::new(&p).with_pipeline(pl).run(op, &mut bufs).unwrap();
                assert_eq!(
                    plan.total_wire_bytes(),
                    serial.total_wire_bytes(),
                    "{} wire bytes drift under {pl:?} on {p:?}",
                    op.name()
                );
                assert_eq!(
                    plan.n_base_rounds(),
                    serial.n_base_rounds(),
                    "{} latency rounds drift under {pl:?} on {p:?}",
                    op.name()
                );
                let sched = transcode_plan(&p, &plan).unwrap();
                let report = fabric.execute(&sched);
                assert!(
                    report.ok(),
                    "{} under {pl:?} violates fabric rules on {p:?}: {:?}",
                    op.name(),
                    report.violations
                );
                assert_eq!(report.wire_bytes, plan.total_wire_bytes(), "{}", op.name());
            }
        }
    }
}

#[test]
fn arena_capacity_covers_every_closed_form_phase() {
    // the executor pre-sizes regions from ramp_phases; every phase of the
    // full-network closed form (and the job closed form at full size,
    // which must coincide) has to fit
    for p in fabrics() {
        let n = p.n_nodes();
        for op in MpiOp::all() {
            if matches!(op, MpiOp::Broadcast { .. }) {
                // broadcast replicates the root buffer over a multicast
                // tree; its PhaseSpec models tree stages, not per-node
                // buffer growth (arena_capacity special-cases it)
                continue;
            }
            for elems in [n, 2 * n, 7 * n] {
                let cap_bytes = (arena_capacity(&p, op, elems) * 4) as u64;
                let m = (elems * 4) as u64;
                for ph in ramp_phases(&p, op, m) {
                    let per_node = ph.per_peer_bytes * ph.size as u64;
                    assert!(
                        per_node <= cap_bytes,
                        "{}: phase at {:?} needs {per_node} B > cap {cap_bytes} B on {p:?}",
                        op.name(),
                        ph.step
                    );
                }
                for ph in job_phases(&p, op, m, n) {
                    let per_node = ph.per_peer_bytes * ph.size as u64;
                    assert!(
                        per_node <= cap_bytes,
                        "{}: job phase needs {per_node} B > cap {cap_bytes} B on {p:?}",
                        op.name()
                    );
                }
            }
        }
    }
}

#[test]
fn arena_capacity_survives_every_executor_path() {
    // end-to-end sufficiency: BufferArena::for_op + run must never trip
    // the executors' internal region-capacity guards, for any op, shape,
    // padding-edge size, or chunking
    for p in fabrics() {
        let n = p.n_nodes();
        for op in MpiOp::all() {
            let sizes = match op {
                MpiOp::AllGather | MpiOp::Gather { .. } => contribution_sizes(),
                MpiOp::Broadcast { .. } | MpiOp::Barrier => vec![1, 17],
                _ => vec![n, padded_len(&p, n + 1)],
            };
            for elems in sizes {
                let inputs = random_inputs(n, elems, 3);
                let mut arena = BufferArena::for_op(&p, op, &inputs).unwrap();
                RampX::pipelined(&p).run_arena(op, &mut arena).unwrap();
            }
        }
    }
}

#[test]
fn job_step_growth_stays_within_padding_bound() {
    // partial-job phase lists are estimator-only (the data plane always
    // runs the full network); their growth is bounded by the ≤ 4·n
    // factor-product guarantee of job_step_sizes, which this pins down
    for p in fabrics() {
        let full = p.n_nodes();
        for n in [2usize, 3, full / 2, full - 1, full] {
            if n < 2 {
                continue;
            }
            let prod: usize = job_step_sizes(&p, n).iter().product();
            assert!(prod >= n.min(full) && prod <= 4 * n, "prod {prod} for n={n} on {p:?}");
        }
    }
}

// ---- randomized differential fuzz ---------------------------------------

/// Tiny seeded LCG (Knuth MMIX constants) for drawing fuzz *cases*.
/// Deliberately separate from `ramp::rng::Xoshiro256` (which generates
/// the input *payloads*): the case-drawing stream must stay
/// self-contained and frozen so a printed case seed replays the same
/// grid point even if the crate RNG ever changes.
struct Lcg(u64);

impl Lcg {
    fn new(seed: u64) -> Self {
        // avoid the degenerate all-zero stream start
        Self(seed ^ 0x9e37_79b9_7f4a_7c15)
    }

    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0
    }

    /// Uniform-ish draw in `[0, n)` from the high bits.
    fn below(&mut self, n: usize) -> usize {
        ((self.next() >> 33) % n as u64) as usize
    }

    fn pick<'v, T>(&mut self, v: &'v [T]) -> &'v T {
        &v[self.below(v.len())]
    }
}

/// One randomly drawn differential case: (fabric, op incl. root
/// placement, payload size incl. <16 KiB and padding edges, chunk mode
/// incl. cross-step, execution substrate), checked **bitwise** against
/// the scoped serial anchor — and the anchor itself against the
/// reference oracle. Panics with the case seed for replay.
fn run_fuzz_case(seed: u64) {
    let mut rng = Lcg::new(seed);
    let fabric_set = fabrics();
    let p = rng.pick(&fabric_set).clone();
    let n = p.n_nodes();
    let oi = rng.below(op_instances(n).len());
    let op = op_instances(n)[oi];
    let sizes = match op {
        // contributions: tiny, non-pow2, and a 16 KiB-edge straddler
        MpiOp::AllGather | MpiOp::Gather { .. } => vec![1, 2, 3, 8, 13, 64, 257],
        MpiOp::Broadcast { .. } => vec![1, 2, 64, 257, 4099],
        MpiOp::Barrier => vec![1],
        // N-divisible: minimum, the padding edge above it, non-pow2
        // multiples, and a multi-strip payload (still < 16 KiB/chunk so
        // the auto floor keeps small messages whole)
        _ => vec![n, padded_len(&p, n + 1), 2 * n, 3 * n, 7 * n, 16 * n],
    };
    let elems = *rng.pick(&sizes);
    let modes = [
        Pipeline::off(),
        Pipeline::fixed(2),
        Pipeline::fixed(3),
        Pipeline::fixed(5),
        Pipeline::fixed(16),
        Pipeline::auto(),
        Pipeline::cross(0),
        Pipeline::cross(2),
        Pipeline::cross(3),
        Pipeline::cross(16),
    ];
    let pl = *rng.pick(&modes);
    let pooled = rng.below(2) == 1;
    // lane-driver axis (PR 5): event-driven single-fan-out executor vs
    // the PR-4 in-order driver (only meaningful for cross modes, drawn
    // unconditionally to keep the seed stream stable)
    let driver = if rng.below(2) == 1 { LaneDriver::Event } else { LaneDriver::InOrder };
    let inputs = random_inputs(n, elems, seed ^ 0xf00d);

    let mut anchor = inputs.clone();
    RampX::new(&p).with_pool(PoolSel::Off).run(op, &mut anchor).unwrap();
    if let Some(expect) = oracle(op, &inputs) {
        assert_close(
            &anchor,
            &expect,
            is_movement_only(op),
            &format!("fuzz seed {seed}: {} anchor vs oracle m={elems} on {p:?}", op.name()),
        );
    }
    let substrate: PoolSel =
        if pooled { PoolSel::Forced(shared_pool()) } else { PoolSel::Off };
    let mut got = inputs.clone();
    RampX::new(&p)
        .with_pipeline(pl)
        .with_pool(substrate)
        .with_lane_driver(driver)
        .run(op, &mut got)
        .unwrap();
    assert_eq!(
        got,
        anchor,
        "fuzz seed {seed}: {} diverged bitwise under {pl:?} ({}, {driver:?}) m={elems} on {p:?}",
        op.name(),
        if pooled { "pooled" } else { "scoped" }
    );
}

/// Drive `cases` fuzz cases from a fixed master seed. On the first
/// failure the failing case seed is written to
/// `target/fuzz-failing-seed.txt` (CI uploads it as an artifact) and the
/// panic message names it; replay exactly that case with
/// `RAMP_FUZZ_REPLAY=<seed> cargo test -q fuzz_differential`.
fn run_fuzz(cases: usize) {
    if let Some(seed) = ramp::config::fuzz_replay_seed() {
        run_fuzz_case(seed);
        return;
    }
    // drop any stale seed from a previous run: CI caches target/ and
    // uploads the file on *any* job failure, so a leftover seed would
    // point at a case this run never failed
    let _ = std::fs::remove_file("target/fuzz-failing-seed.txt");
    let mut master = Lcg::new(0x5eed_2026);
    for i in 0..cases {
        let seed = master.next();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_fuzz_case(seed);
        }));
        if let Err(payload) = outcome {
            let _ = std::fs::create_dir_all("target");
            let _ = std::fs::write(
                "target/fuzz-failing-seed.txt",
                format!("case {i} of {cases}: seed {seed}\n"),
            );
            eprintln!(
                "fuzz case {i} FAILED — replay with: RAMP_FUZZ_REPLAY={seed} \
                 cargo test -q fuzz_differential"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

#[test]
fn fuzz_differential_matrix() {
    // tier-1 profile: 200 cases (override with RAMP_FUZZ_CASES)
    run_fuzz(ramp::config::fuzz_cases_override().unwrap_or(200));
}

#[test]
#[ignore = "long fuzz profile — run via `cargo test --release -- --ignored` (nightly CI job)"]
fn fuzz_differential_matrix_long() {
    // nightly-style profile: 2000 cases (override with RAMP_FUZZ_CASES)
    run_fuzz(ramp::config::fuzz_cases_override().unwrap_or(2000));
}

// ---- recovery fuzz axis (PR 8) -------------------------------------------

/// One randomly drawn **recovery** case: a seeded mid-flight fault
/// (worker panics, lost publishes, or a `trx-at` transceiver death) ×
/// op × fabric × chunk count, executed under the supervisory retry
/// loop. The contract fuzzed: the run either completes **bitwise
/// identical to the fault-free anchor** (recovered — possibly via
/// quarantine + degraded replan + partial-progress resume) or surfaces
/// a typed [`ramp::fault::RampError`] after exhausting the budget.
/// Anything else — divergent floats, an untyped error — fails with the
/// case seed for replay.
fn run_recovery_fuzz_case(seed: u64) {
    use ramp::engine::RampEngine;
    use ramp::fault::recovery::RecoveryPolicy;
    use ramp::fault::{FaultPlan, RampError};

    let mut rng = Lcg::new(seed ^ 0x5afe_c0de);
    let fabric_set = fabrics();
    let p = rng.pick(&fabric_set).clone();
    let n = p.n_nodes();
    let oi = rng.below(op_instances(n).len());
    let op = op_instances(n)[oi];
    let sizes = match op {
        MpiOp::AllGather | MpiOp::Gather { .. } => vec![3, 8, 13],
        MpiOp::Broadcast { .. } => vec![2, 64, 257],
        MpiOp::Barrier => vec![1],
        _ => vec![n, 2 * n, 3 * n],
    };
    let elems = *rng.pick(&sizes);
    let pl = *rng.pick(&[Pipeline::cross(2), Pipeline::cross(3), Pipeline::fixed(3)]);
    let plan = match rng.below(3) {
        0 => FaultPlan {
            seed,
            panic_permille: *rng.pick(&[5u32, 20, 60]),
            ..FaultPlan::default()
        },
        1 => FaultPlan {
            seed,
            lose_permille: *rng.pick(&[5u32, 20, 60]),
            watchdog_ms: 40,
            ..FaultPlan::default()
        },
        _ => FaultPlan {
            seed,
            trx_at: vec![(rng.below(p.x), rng.below(3))],
            watchdog_ms: 400,
            ..FaultPlan::default()
        },
    };
    let inputs = random_inputs(n, elems, seed ^ 0xbeef);

    let mut anchor = inputs.clone();
    RampEngine::new(p.clone()).with_pipeline(pl).execute(op, &mut anchor).unwrap();

    let policy = RecoveryPolicy { max_retries: 6, ..RecoveryPolicy::default() };
    let mut engine = RampEngine::new(p.clone()).with_pipeline(pl).with_faults(plan);
    let mut got = inputs.clone();
    match engine.execute_with_recovery(op, &mut got, &policy) {
        Ok((run, stats)) => {
            assert_eq!(
                got,
                anchor,
                "recovery fuzz seed {seed}: {} recovered non-bitwise under {pl:?} \
                 m={elems} on {p:?} (retries {})",
                op.name(),
                stats.retries
            );
            assert!(
                run.report.ok(),
                "recovery fuzz seed {seed}: recovered schedule violates the fabric: {:?}",
                run.report.violations
            );
        }
        Err(err) => {
            assert!(
                err.downcast_ref::<RampError>().is_some(),
                "recovery fuzz seed {seed}: exhaustion must stay typed, got {err:#}"
            );
        }
    }
}

/// Drive `cases` recovery fuzz cases. Mirrors [`run_fuzz`]: a failing
/// case seed is written to `target/fuzz-recovery-failing-seed.txt` and
/// replayed exactly with `RAMP_FUZZ_REPLAY=<seed> cargo test -q
/// fuzz_recovery_matrix`.
fn run_recovery_fuzz(cases: usize) {
    if let Some(seed) = ramp::config::fuzz_replay_seed() {
        run_recovery_fuzz_case(seed);
        return;
    }
    let _ = std::fs::remove_file("target/fuzz-recovery-failing-seed.txt");
    let mut master = Lcg::new(0x5eed_8008);
    for i in 0..cases {
        let seed = master.next();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_recovery_fuzz_case(seed);
        }));
        if let Err(payload) = outcome {
            let _ = std::fs::create_dir_all("target");
            let _ = std::fs::write(
                "target/fuzz-recovery-failing-seed.txt",
                format!("case {i} of {cases}: seed {seed}\n"),
            );
            eprintln!(
                "recovery fuzz case {i} FAILED — replay with: RAMP_FUZZ_REPLAY={seed} \
                 cargo test -q fuzz_recovery_matrix"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

#[test]
fn fuzz_recovery_matrix() {
    // tier-1 profile: recovery cases cost several engine attempts each,
    // so the budget sits an order below the differential matrix (scale
    // with RAMP_FUZZ_CASES, floored so the axis never vanishes)
    let cases = ramp::config::fuzz_cases_override().map(|c| (c / 8).max(5)).unwrap_or(25);
    run_recovery_fuzz(cases);
}

// ---- elastic rank-death fuzz axis (PR 10) --------------------------------

/// One randomly drawn **elastic** case: a seeded whole-rank death
/// (`rank-at=R:S`) × op × fabric × chunk count × redundancy policy,
/// executed under the supervisory loop with `--elastic` armed. The
/// contract fuzzed: when the death fires the group reforms and the
/// survivors' results are **bitwise identical** to the direct
/// reformation anchor (the same remap → reconcile → replan pass run
/// standalone — itself pinned to the reference oracles by the
/// `fault::elastic` module tests) with the dead region emptied; when
/// the armed site is never reached (shallow program — broadcast and
/// barrier never tick the lane executor) the run must equal the
/// fault-free full-N anchor with no reformation counted; a dead root
/// must surface typed. Anything else fails with the case seed.
fn run_elastic_fuzz_case(seed: u64) {
    use ramp::engine::RampEngine;
    use ramp::fault::elastic::{ElasticExec, ElasticPolicy, Reformation};
    use ramp::fault::recovery::RecoveryPolicy;
    use ramp::fault::{FaultPlan, RampError};

    let mut rng = Lcg::new(seed ^ 0xe1a5_71c5);
    let fabric_set = fabrics();
    let p = rng.pick(&fabric_set).clone();
    let n = p.n_nodes();
    let oi = rng.below(op_instances(n).len());
    let op = op_instances(n)[oi];
    let sizes = match op {
        MpiOp::AllGather | MpiOp::Gather { .. } => vec![1, 3, 8, 13],
        MpiOp::Broadcast { .. } => vec![2, 64, 257],
        MpiOp::Barrier => vec![1],
        // the reformed group has n−1 ranks: reduce-scatter and
        // all-to-all need the payload divisible at both memberships
        _ => vec![n * (n - 1), 2 * n * (n - 1)],
    };
    let elems = *rng.pick(&sizes);
    let pl = *rng.pick(&[Pipeline::cross(2), Pipeline::cross(3)]);
    let dead = rng.below(n);
    let step = rng.below(3);
    let policy = if rng.below(2) == 1 {
        ElasticPolicy::RestoreFrom
    } else {
        ElasticPolicy::Drop
    };
    let inputs = random_inputs(n, elems, seed ^ 0xdead);

    let mut anchor_full = inputs.clone();
    RampEngine::new(p.clone()).with_pipeline(pl).execute(op, &mut anchor_full).unwrap();

    let mut engine = RampEngine::new(p.clone())
        .with_pipeline(pl)
        .with_faults(FaultPlan {
            seed,
            rank_at: vec![(dead, step)],
            watchdog_ms: 400,
            ..FaultPlan::default()
        })
        .with_elastic(policy);
    engine.pool = PoolSel::Forced(shared_pool());
    let mut got = inputs.clone();
    match engine.execute_with_recovery(op, &mut got, &RecoveryPolicy::default()) {
        Ok((_, stats)) => {
            if engine.dead_ranks().is_empty() {
                assert_eq!(stats.reformations, 0, "elastic fuzz seed {seed}: no death, no reform");
                assert_eq!(
                    got,
                    anchor_full,
                    "elastic fuzz seed {seed}: {} unfired death diverged from the \
                     fault-free anchor under {pl:?} m={elems} on {p:?}",
                    op.name()
                );
                return;
            }
            assert_eq!(stats.dead_ranks, vec![dead], "elastic fuzz seed {seed}");
            let reform = Reformation::new(n, &[dead], policy).unwrap();
            let op2 = reform.group.remap_op(op).unwrap();
            let (mut bufs, _) = reform.rebased_inputs(op, &inputs).unwrap();
            ElasticExec::new(&p, &reform.group).run(op2, &mut bufs).unwrap();
            assert!(
                got[dead].is_empty(),
                "elastic fuzz seed {seed}: dead region must be emptied"
            );
            for (i, &old) in reform.group.survivors.iter().enumerate() {
                assert_eq!(
                    got[old],
                    bufs[i],
                    "elastic fuzz seed {seed}: {} survivor {old} diverged from the \
                     reformation anchor ({}) under {pl:?} m={elems} on {p:?}",
                    op.name(),
                    policy.name()
                );
            }
        }
        Err(err) => {
            // with one armed death the only legitimate failure is an
            // unrecoverable dead root — and it must stay typed
            let root_died = matches!(
                err.downcast_ref::<RampError>(),
                Some(RampError::RankDied { rank, .. }) if *rank == dead
            ) && matches!(
                op,
                MpiOp::Scatter { root } | MpiOp::Gather { root }
                | MpiOp::Reduce { root } | MpiOp::Broadcast { root } if root == dead
            );
            assert!(
                root_died,
                "elastic fuzz seed {seed}: {} must reform or fail typed on a dead \
                 root, got {err:#}",
                op.name()
            );
        }
    }
}

/// Drive `cases` elastic fuzz cases. Mirrors [`run_fuzz`]: a failing
/// case seed is written to `target/fuzz-elastic-failing-seed.txt` and
/// replayed exactly with `RAMP_FUZZ_REPLAY=<seed> cargo test -q
/// fuzz_elastic_matrix`.
fn run_elastic_fuzz(cases: usize) {
    if let Some(seed) = ramp::config::fuzz_replay_seed() {
        run_elastic_fuzz_case(seed);
        return;
    }
    let _ = std::fs::remove_file("target/fuzz-elastic-failing-seed.txt");
    let mut master = Lcg::new(0x5eed_e1a5);
    for i in 0..cases {
        let seed = master.next();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_elastic_fuzz_case(seed);
        }));
        if let Err(payload) = outcome {
            let _ = std::fs::create_dir_all("target");
            let _ = std::fs::write(
                "target/fuzz-elastic-failing-seed.txt",
                format!("case {i} of {cases}: seed {seed}\n"),
            );
            eprintln!(
                "elastic fuzz case {i} FAILED — replay with: RAMP_FUZZ_REPLAY={seed} \
                 cargo test -q fuzz_elastic_matrix"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

#[test]
fn fuzz_elastic_matrix() {
    // tier-1 profile: each case pays a full attempt plus a reformation,
    // so the budget matches the recovery axis (scales with
    // RAMP_FUZZ_CASES, floored so the axis never vanishes)
    let cases = ramp::config::fuzz_cases_override().map(|c| (c / 8).max(5)).unwrap_or(25);
    run_elastic_fuzz(cases);
}

// ---- cross-step lane-schedule validity ----------------------------------

#[test]
fn cross_step_lane_schedules_are_valid_and_conserve_wire_bytes() {
    // satellite properties of the dependency graph: every (chunk, step)
    // appears exactly once, dependencies precede their dependents, waves
    // respect dependencies (all checked by validate()); wire totals stay
    // chunk- and schedule-invariant against the serial plan
    use ramp::transcoder::lanes::LaneSchedule;
    use ramp::transcoder::transcode_plan_lanes;
    for p in fabrics() {
        let n = p.n_nodes();
        let fabric = OpticalFabric::new(p.clone());
        for op in [
            MpiOp::ReduceScatter,
            MpiOp::AllGather,
            MpiOp::AllReduce,
            MpiOp::AllToAll,
            MpiOp::Scatter { root: n / 2 },
            MpiOp::Gather { root: 0 },
            MpiOp::Reduce { root: n - 1 },
        ] {
            let elems = match op {
                MpiOp::AllGather | MpiOp::Gather { .. } => 6,
                _ => 2 * n,
            };
            let mut serial_bufs = random_inputs(n, elems, 77);
            let serial = RampX::new(&p).run(op, &mut serial_bufs).unwrap();
            for pl in [Pipeline::cross(2), Pipeline::cross(3), Pipeline::cross(0)] {
                let mut bufs = random_inputs(n, elems, 77);
                let plan = RampX::new(&p).with_pipeline(pl).run(op, &mut bufs).unwrap();
                let sched = LaneSchedule::from_plan(&plan);
                sched.validate(&plan).unwrap();
                assert_eq!(
                    plan.total_wire_bytes(),
                    serial.total_wire_bytes(),
                    "{} wire bytes drift under {pl:?} on {p:?}",
                    op.name()
                );
                assert_eq!(plan.n_base_rounds(), serial.n_base_rounds(), "{}", op.name());
                // chunked cross plans must actually exploit every
                // boundary (no hidden barriers)
                let k = plan.steps[0].n_chunks;
                assert!(plan.steps.iter().all(|s| s.n_chunks == k && s.lane_aligned));
                if k > 1 {
                    assert_eq!(
                        sched.aligned_boundaries(&plan),
                        plan.steps.len() - 1,
                        "{} lane schedule degenerated under {pl:?} on {p:?}",
                        op.name()
                    );
                }
                // the interleaved NIC stream executes violation-free and
                // carries exactly the plan's bytes
                let wire = transcode_plan_lanes(&p, &plan).unwrap();
                let report = fabric.execute(&wire);
                assert!(
                    report.ok(),
                    "{} lane schedule violates fabric rules under {pl:?} on {p:?}: {:?}",
                    op.name(),
                    report.violations
                );
                assert_eq!(report.wire_bytes, plan.total_wire_bytes(), "{}", op.name());
            }
        }
    }
}

#[test]
fn chunked_execution_leaves_no_residue_across_regions() {
    // run a pipelined all-reduce twice on one arena with different data;
    // the second result must show no trace of the first (chunked writes
    // cover their regions exactly — nothing leaks across boundaries or
    // survives a flip)
    for p in [RampParams::new(2, 2, 4, 1), RampParams::fig8_example()] {
        let n = p.n_nodes();
        let x = RampX::new(&p).with_pipeline(Pipeline::fixed(3));
        let first = random_inputs(n, 2 * n, 41);
        let second = random_inputs(n, 2 * n, 42);
        let mut arena = BufferArena::for_op(&p, MpiOp::AllReduce, &first).unwrap();
        x.run_arena(MpiOp::AllReduce, &mut arena).unwrap();
        arena.load(&second).unwrap();
        x.run_arena(MpiOp::AllReduce, &mut arena).unwrap();
        assert_eq!(arena.copy_out(), reference::all_reduce(&second), "residue on {p:?}");
    }
}
