//! Elastic rank-loss integration: whole-node death mid-collective under
//! the supervisory recovery loop, through the **public** engine and
//! coordinator APIs. The contract under test (ISSUE 10 tentpole):
//!
//! 1. a rank armed to die (`rank-at=R:S`) aborts the attempt with a
//!    typed [`RampError::RankDied`];
//! 2. with an `--elastic` policy armed the group reforms over the
//!    survivors (remap → reconcile → replan → resume) and every op —
//!    all nine — completes with results **bitwise equal** to the
//!    reformed (N−1)-rank run under `drop` semantics;
//! 3. executed wire bytes sit exactly on the reformed closed forms;
//! 4. `restore-from` re-contributes the dead rank's input, so the
//!    reformed reduction equals the fault-free full-N run bitwise;
//! 5. exhaustion and unrecoverable cases (no policy, dead root, fewer
//!    than two survivors) surface typed — never a hang, never a silent
//!    partial result.
//!
//! Every scenario runs under a spawned-thread hang guard (the chaos
//! suite's discipline): a deadlocked reformation fails loudly instead
//! of wedging CI.

use ramp::collectives::arena::Pipeline;
use ramp::collectives::pool::{PoolSel, WorkerPool};
use ramp::collectives::{reference, MpiOp};
use ramp::engine::{fabric_for_workers, RampEngine};
use ramp::fault::elastic::{elastic_wire_bytes, ElasticExec, ElasticPolicy, Reformation};
use ramp::fault::{FaultPlan, RampError};
use ramp::rng::Xoshiro256;
use ramp::topology::ramp::RampParams;
use std::sync::Arc;
use std::time::Duration;

/// Chaos-style hang guard: run `f` on its own thread, fail the test if
/// it has not produced a value within `secs`.
fn with_timeout<T: Send + 'static>(
    secs: u64,
    what: &str,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    let tag = what.to_string();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => v,
        Err(_) => panic!("{tag}: hung past the {secs}s elastic guard"),
    }
}

/// Integer-valued inputs: float sums of small integers are exact under
/// any association order, so reformed results can be compared bitwise
/// across differently-shaped reduction trees.
fn int_inputs(n: usize, elems: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut r = Xoshiro256::seed_from(seed);
    (0..n)
        .map(|_| (0..elems).map(|_| (r.next_below(100) as f32) + 1.0).collect())
        .collect()
}

/// Direct reformed anchor through the public `fault::elastic` API: the
/// same remap → reconcile → replan pass the engine's supervisory loop
/// runs, mapped back to the original rank indexing with dead regions
/// empty.
fn elastic_anchor(
    p: &RampParams,
    n: usize,
    dead: &[usize],
    policy: ElasticPolicy,
    op: MpiOp,
    inputs: &[Vec<f32>],
) -> Vec<Vec<f32>> {
    let reform = Reformation::new(n, dead, policy).unwrap();
    let op2 = reform.group.remap_op(op).unwrap();
    let (mut bufs, _) = reform.rebased_inputs(op, inputs).unwrap();
    ElasticExec::new(p, &reform.group).run(op2, &mut bufs).unwrap();
    let mut out = vec![Vec::new(); n];
    for (i, &old) in reform.group.survivors.iter().enumerate() {
        out[old] = std::mem::take(&mut bufs[i]);
    }
    out
}

/// Engine wired the way the chaos suite runs cross-step programs: a
/// forced pool (so the event-driven lane executor — the only site where
/// an armed rank death can fire mid-schedule — runs even on tiny test
/// payloads), a watchdog, and `--elastic drop`.
fn elastic_engine(p: &RampParams, rank_at: Vec<(usize, usize)>) -> RampEngine {
    let mut engine = RampEngine::new(p.clone())
        .with_pipeline(Pipeline::cross(2))
        .with_faults(FaultPlan { seed: 13, rank_at, watchdog_ms: 400, ..FaultPlan::default() })
        .with_elastic(ElasticPolicy::Drop);
    engine.pool = PoolSel::Forced(Arc::new(WorkerPool::new(2)));
    engine
}

fn elems_for(op: MpiOp) -> usize {
    match op {
        MpiOp::AllGather | MpiOp::Gather { .. } => 4,
        MpiOp::Broadcast { .. } => 17,
        // divisible by both the full N=16 and the reformed 15
        _ => 240,
    }
}

/// Tentpole acceptance: every lane op survives a seeded single-rank
/// death mid-schedule — one typed abort, one reformation, survivors
/// bitwise on the reformed anchor, wire bytes exactly on the reformed
/// closed forms. (Broadcast and barrier never tick the lane executor;
/// their elastic routing is covered by the steady-state test below.)
#[test]
fn mid_schedule_rank_death_reforms_every_lane_op() {
    with_timeout(240, "mid-schedule rank death", || {
        let p = fabric_for_workers(16).unwrap();
        let dead = 5usize;
        for op in [
            MpiOp::ReduceScatter,
            MpiOp::AllGather,
            MpiOp::AllReduce,
            MpiOp::AllToAll,
            MpiOp::Scatter { root: 3 },
            MpiOp::Gather { root: 3 },
            MpiOp::Reduce { root: 3 },
        ] {
            let elems = elems_for(op);
            let inputs = int_inputs(16, elems, 61);
            let mut engine = elastic_engine(&p, vec![(dead, 0)]);
            let mut bufs = inputs.clone();
            let (run, stats) =
                engine.execute_with_recovery(op, &mut bufs, &Default::default()).unwrap();
            assert_eq!(stats.retries, 1, "{}: one absorbed abort", op.name());
            assert_eq!(stats.reformations, 1, "{}", op.name());
            assert_eq!(stats.dead_ranks, vec![dead], "{}", op.name());
            assert_eq!(engine.dead_ranks(), &[dead], "{}", op.name());
            assert_eq!(engine.membership_epoch(), 1, "{}", op.name());
            let anchor = elastic_anchor(&p, 16, &[dead], ElasticPolicy::Drop, op, &inputs);
            assert_eq!(bufs, anchor, "{} diverged from the reformed anchor", op.name());
            assert_eq!(
                run.report.wire_bytes,
                elastic_wire_bytes(&p, op, (elems * 4) as u64, 15),
                "{} executed wire bytes off the reformed closed form",
                op.name()
            );
            assert!(run.completion_time() > 0.0, "{}", op.name());
        }
    });
}

/// `drop` semantics against an **independent** oracle: the survivors'
/// reformed results must equal the naive reference collectives computed
/// over just the survivors' inputs — i.e. a fault-free (N−1)-rank run.
#[test]
fn drop_semantics_match_the_reference_oracle_at_n_minus_one() {
    with_timeout(120, "drop vs (N-1) reference", || {
        let p = fabric_for_workers(16).unwrap();
        let dead = 5usize;
        let survivors: Vec<usize> = (0..16).filter(|&r| r != dead).collect();
        for op in [MpiOp::AllReduce, MpiOp::ReduceScatter, MpiOp::AllGather] {
            let elems = elems_for(op);
            let inputs = int_inputs(16, elems, 43);
            let shrunk: Vec<Vec<f32>> =
                survivors.iter().map(|&r| inputs[r].clone()).collect();
            let expect = match op {
                MpiOp::AllReduce => reference::all_reduce(&shrunk),
                MpiOp::ReduceScatter => reference::reduce_scatter(&shrunk),
                _ => reference::all_gather(&shrunk),
            };
            let mut engine = elastic_engine(&p, vec![(dead, 0)]);
            let mut bufs = inputs.clone();
            engine.execute_with_recovery(op, &mut bufs, &Default::default()).unwrap();
            assert!(bufs[dead].is_empty(), "{}: dead region must be emptied", op.name());
            for (i, &r) in survivors.iter().enumerate() {
                assert_eq!(
                    bufs[r],
                    expect[i],
                    "{}: survivor {r} diverged from the fault-free 15-rank oracle",
                    op.name()
                );
            }
        }
    });
}

/// Once the membership has shrunk, **all nine ops** — including
/// broadcast and barrier, whose full-N paths never tick the lane
/// executor — route through the elastic data plane at the surviving
/// membership without new reformations or epoch advances.
#[test]
fn reformed_membership_routes_all_nine_ops() {
    with_timeout(240, "steady-state elastic routing", || {
        let p = fabric_for_workers(16).unwrap();
        let dead = 11usize;
        let mut engine = elastic_engine(&p, vec![(dead, 0)]);
        let mut first = int_inputs(16, 240, 67);
        engine
            .execute_with_recovery(MpiOp::AllReduce, &mut first, &Default::default())
            .unwrap();
        assert_eq!(engine.dead_ranks(), &[dead]);
        for op in MpiOp::all() {
            let elems = elems_for(op);
            let inputs = int_inputs(16, elems, 71);
            let mut bufs = inputs.clone();
            let (run, stats) =
                engine.execute_with_recovery(op, &mut bufs, &Default::default()).unwrap();
            assert_eq!(stats.reformations, 0, "{}: steady state reforms nothing", op.name());
            assert_eq!(stats.retries, 0, "{}", op.name());
            let anchor = elastic_anchor(&p, 16, &[dead], ElasticPolicy::Drop, op, &inputs);
            assert_eq!(bufs, anchor, "{} diverged at steady state", op.name());
            assert!(run.report.wire_bytes > 0, "{}", op.name());
        }
        assert_eq!(engine.membership_epoch(), 1, "steady state must not advance the epoch");
    });
}

/// `restore-from`: the reformed all-reduce re-contributes the dead
/// rank's input from the peer-held replica, so every survivor ends with
/// the fault-free **full-N** sum bitwise.
#[test]
fn restore_from_reduction_equals_the_full_group_run() {
    with_timeout(120, "restore-from reduction", || {
        let p = fabric_for_workers(16).unwrap();
        let dead = 5usize;
        let inputs = int_inputs(16, 240, 73);
        let full = reference::all_reduce(&inputs);
        let mut engine =
            elastic_engine(&p, vec![(dead, 0)]).with_elastic(ElasticPolicy::RestoreFrom);
        let mut bufs = inputs.clone();
        let (_, stats) = engine
            .execute_with_recovery(MpiOp::AllReduce, &mut bufs, &Default::default())
            .unwrap();
        assert_eq!(stats.reconciled_bytes, 240 * 4, "one replica shard re-contributed");
        for (r, b) in bufs.iter().enumerate() {
            if r == dead {
                assert!(b.is_empty(), "the dead region must be emptied");
            } else {
                assert_eq!(b, &full[r], "survivor {r} must hold the full-N sum");
            }
        }
    });
}

/// Without an elastic policy a rank death is final even with retry
/// budget left: the typed error surfaces unchanged.
#[test]
fn rank_death_stays_typed_without_an_elastic_policy() {
    with_timeout(120, "unarmed rank death", || {
        let p = fabric_for_workers(16).unwrap();
        let mut engine = RampEngine::new(p)
            .with_pipeline(Pipeline::cross(2))
            .with_faults(FaultPlan {
                seed: 17,
                rank_at: vec![(2, 0)],
                watchdog_ms: 400,
                ..FaultPlan::default()
            });
        engine.pool = PoolSel::Forced(Arc::new(WorkerPool::new(2)));
        let mut bufs = int_inputs(16, 240, 79);
        let err = engine
            .execute_with_recovery(MpiOp::AllReduce, &mut bufs, &Default::default())
            .unwrap_err();
        assert!(
            matches!(err.downcast_ref::<RampError>(), Some(RampError::RankDied { rank: 2, .. })),
            "expected a typed rank death, got {err:#}"
        );
    });
}

/// The unrecoverable edges stay typed: a dead root cannot be re-rooted
/// under any policy, and losing all but one rank exhausts the elastic
/// budget with [`RampError::NoSurvivingRanks`].
#[test]
fn dead_root_and_exhaustion_stay_typed() {
    with_timeout(120, "typed elastic edges", || {
        let p = fabric_for_workers(16).unwrap();
        let mut engine = elastic_engine(&p, vec![(3, 0)]);
        let mut bufs = int_inputs(16, 4, 83);
        let err = engine
            .execute_with_recovery(MpiOp::Gather { root: 3 }, &mut bufs, &Default::default())
            .unwrap_err();
        assert!(
            matches!(err.downcast_ref::<RampError>(), Some(RampError::RankDied { rank: 3, .. })),
            "a dead root cannot be re-rooted, got {err:#}"
        );
        let mut engine = elastic_engine(&p, (0..15).map(|r| (r, 0)).collect());
        let mut bufs = int_inputs(16, 240, 89);
        let err = engine
            .execute_with_recovery(MpiOp::AllReduce, &mut bufs, &Default::default())
            .unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<RampError>(),
                Some(RampError::NoSurvivingRanks { survivors: 1 })
            ),
            "expected typed elastic exhaustion, got {err:#}"
        );
    });
}

/// End-to-end elastic **training** (requires `make artifacts`; skips
/// with a notice otherwise): a worker dies during the first step's
/// gradient all-reduce, the job reforms and finishes every remaining
/// step at the shrunken membership, and the report records the loss.
#[test]
fn elastic_training_survives_a_worker_death() {
    use ramp::coordinator::{train, TrainConfig};
    if let Err(e) = ramp::runtime::Runtime::open(ramp::config::artifacts_dir()) {
        eprintln!("skipping (run `make artifacts`): {e:#}");
        return;
    }
    // the tiny model's ~0.6M-element gradient sits far above the
    // parallel threshold, so the cross-step data plane fans out through
    // the event-driven lane executor — the only site where an armed
    // rank death can fire mid-schedule
    with_timeout(300, "elastic training", || {
        let dead = 5usize;
        let cfg = TrainConfig {
            n_workers: 8,
            steps: 6,
            log_every: 2,
            pipeline_cross: true,
            pipeline_chunks: 2,
            pool_threads: 3,
            faults: Some(FaultPlan {
                seed: 31,
                rank_at: vec![(dead, 0)],
                watchdog_ms: 400,
                ..FaultPlan::default()
            }),
            elastic: Some(ElasticPolicy::Drop),
            ..Default::default()
        };
        let rep = train(&cfg).expect("elastic training failed");
        assert_eq!(rep.dead_workers, vec![dead], "the armed worker must be lost");
        assert_eq!(rep.membership_epoch, 1, "one reformation");
        assert_eq!(rep.recovery.dead_ranks, vec![dead]);
        assert!(rep.recovery.reformations >= 1);
        let last = rep.stats.last().expect("stats recorded");
        assert_eq!(last.live_workers, cfg.n_workers - 1, "training continued at N-1");
        assert!(rep.last_loss().is_finite());
        assert!(rep.total_comm_virtual_s > 0.0);
    });
}
