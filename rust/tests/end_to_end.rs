//! End-to-end integration: PJRT runtime round-trips and a short real
//! training run through the full three-layer stack. Requires
//! `make artifacts`; tests skip (pass with a notice) when artifacts are
//! missing so `cargo test` works in a fresh checkout.

use ramp::coordinator::{train, TrainConfig};
use ramp::runtime::{f32_vec, lit_f32_2d, lit_scalar_i32, Runtime};

fn runtime() -> Option<Runtime> {
    match Runtime::open(ramp::config::artifacts_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn pjrt_reduce_kernel_roundtrip() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("reduce_xto1_4x8192").unwrap();
    let data: Vec<f32> = (0..4 * 8192).map(|i| (i % 100) as f32 * 0.01).collect();
    let out = exe.run(&[lit_f32_2d(&data, 4, 8192).unwrap()]).unwrap();
    let sum = f32_vec(&out[0]).unwrap();
    assert_eq!(sum.len(), 8192);
    for (j, s) in sum.iter().enumerate().take(64) {
        let expect: f32 = (0..4).map(|r| data[r * 8192 + j]).sum();
        assert!((s - expect).abs() < 1e-4, "elem {j}: {s} vs {expect}");
    }
}

#[test]
fn pjrt_model_init_deterministic() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("tiny_init").unwrap();
    let a = f32_vec(&exe.run(&[lit_scalar_i32(7)]).unwrap()[0]).unwrap();
    let b = f32_vec(&exe.run(&[lit_scalar_i32(7)]).unwrap()[0]).unwrap();
    let c = f32_vec(&exe.run(&[lit_scalar_i32(8)]).unwrap()[0]).unwrap();
    assert_eq!(a, b, "same seed must reproduce");
    assert_ne!(a, c, "different seeds must differ");
    let n = rt.manifest.get_usize("model.tiny.n_params").unwrap();
    assert_eq!(a.len(), n);
}

#[test]
fn short_training_run_converges_and_verifies_fabric() {
    let Some(_) = runtime() else { return };
    let cfg = TrainConfig {
        n_workers: 4,
        steps: 15,
        log_every: 5,
        ..Default::default()
    };
    let rep = train(&cfg).expect("training failed");
    assert!(rep.last_loss() < rep.first_loss(), "{} → {}", rep.first_loss(), rep.last_loss());
    assert!(rep.total_comm_virtual_s > 0.0);
    // every logged step moved the full gradient over the fabric
    for s in &rep.stats {
        assert!(s.wire_bytes as usize >= rep.n_params * 4);
    }
    // EPS baseline must price the same collective slower
    assert!(rep.baseline_comm_virtual_s > rep.total_comm_virtual_s);
}

#[test]
fn eight_worker_fabric_also_trains() {
    let Some(_) = runtime() else { return };
    let cfg = TrainConfig {
        n_workers: 8,
        steps: 6,
        log_every: 2,
        ..Default::default()
    };
    let rep = train(&cfg).expect("training failed");
    assert_eq!(rep.n_workers, 8);
    assert!(rep.last_loss().is_finite());
}
