//! Overflow-boundary regression net for the 65,536-rank scale path:
//! byte accounting must stay exact when rank counts and buffer sizes
//! push products past `u32` (and, on 32-bit hosts, `usize`) range.
//! These pin the widened `u64` arithmetic in the arena capacity
//! planner, the per-node phase accounting, and the estimators.

use ramp::collectives::arena::{arena_capacity, ArenaRegion, Pipeline};
use ramp::collectives::ops::{node_tx_bytes, ramp_phases};
use ramp::collectives::stream::StreamPlan;
use ramp::collectives::MpiOp;
use ramp::engine::RampEngine;
use ramp::estimator::collective_time::CollectiveEstimator;
use ramp::topology::ramp::RampParams;

const GIB: u64 = 1 << 30;

#[test]
fn arena_region_bytes_exact_past_u32() {
    // 2^33 + 5 elements → 2^35 + 20 bytes; a 32-bit (or f64-rounded)
    // multiply would mangle this
    let r = ArenaRegion::new(0, (1usize << 33) + 5);
    assert_eq!(r.bytes(), (1u64 << 35) + 20);
}

#[test]
fn arena_capacity_exact_at_full_scale() {
    let p = RampParams::max_scale();
    assert_eq!(p.n_nodes(), 65536);

    // all-gather grows each contribution by N: 1 MiB/node → 64 GiB of
    // result elements; the elem count (2^24 * 2^16 = 2^40 … /4) must
    // survive the byte math without truncation
    let contrib = 1 << 18; // elems: 1 MiB per node
    let cap = arena_capacity(&p, MpiOp::AllGather, contrib);
    assert_eq!(cap, contrib * 65536);

    // all-reduce at 4 GiB input: capacity covers input + exchange
    // scratch and is phase-accurate, not saturated or wrapped
    let m = (4 * GIB / 4) as usize; // 1 Gi elems
    let cap = arena_capacity(&p, MpiOp::AllReduce, m);
    assert!(cap >= m, "capacity {cap} lost the input");
    assert!((cap as u64) < 64 * GIB / 4, "capacity {cap} wrapped or exploded");
}

#[test]
fn phase_accounting_exact_at_scale_times_multi_gib() {
    let p = RampParams::max_scale();
    // 16 GiB all-reduce on 65,536 nodes: per-node wire bytes fit u64
    // comfortably but overflow u32 per phase
    let phases = ramp_phases(&p, MpiOp::AllReduce, 16 * GIB);
    assert!(!phases.is_empty());
    let tx = node_tx_bytes(&phases);
    // reduce-scatter + all-gather each move < 2 * m per node; exact
    // zero or u32-wrapped values would violate these bounds
    assert!(tx > 16 * GIB, "tx {tx} undercounts a 16 GiB all-reduce");
    assert!(tx < 64 * GIB, "tx {tx} overflowed");

    // all-to-all is the worst case: per-peer bytes * 65k peers
    let phases = ramp_phases(&p, MpiOp::AllToAll, 16 * GIB);
    let tx = node_tx_bytes(&phases);
    assert!(tx > 8 * GIB && tx < 1024 * GIB, "all-to-all tx {tx}");
}

#[test]
fn stream_summary_wire_bytes_exact_at_scale() {
    let p = RampParams::max_scale();
    let n = p.n_nodes();
    // 4 GiB all-reduce: total wire bytes across 65k nodes run to
    // hundreds of TiB — far past u32 * u32 territory
    let m = GIB as usize; // elems → 4 GiB buffer
    let plan = StreamPlan::all_reduce(&p, m, Pipeline::off()).unwrap();
    let s = plan.summary();
    assert!(s.n_transfers > 1_000_000, "n_transfers {}", s.n_transfers);
    // each node wires ~2 * m bytes total across RS + AG; the fabric
    // total must land between N*m and 4*N*m bytes
    let nm = n as u64 * 4 * GIB;
    assert!(s.total_wire_bytes > nm / 4, "wire bytes {} undercount", s.total_wire_bytes);
    assert!(s.total_wire_bytes < 4 * nm, "wire bytes {} overflowed", s.total_wire_bytes);
}

#[test]
fn estimator_finite_at_scale_boundaries() {
    let p = RampParams::max_scale();
    let est = CollectiveEstimator::ramp(&p);
    for m in [4u64, GIB, 16 * GIB] {
        for op in [MpiOp::AllReduce, MpiOp::AllGather, MpiOp::AllToAll] {
            let t = est.completion_time(op, m, 65536);
            assert!(t.total().is_finite() && t.total() > 0.0, "{op:?} m={m}");
        }
    }
}

#[test]
fn probe_scale_reports_consistent_totals() {
    // the engine-level entry point used by benches and callers: one
    // call plans + transcodes + prices in bounded memory
    let p = RampParams::new(16, 16, 16, 1); // 4,096 ranks
    let probe = RampEngine::new(p).probe_scale(MpiOp::AllReduce, 4096 * 4).unwrap();
    assert_eq!(probe.plan.total_wire_bytes, probe.schedule.total_bytes);
    assert!(probe.schedule.n_instructions > 0);
    assert!(probe.time.total().is_finite() && probe.time.total() > 0.0);
}
