//! Pool stress net: whole collectives dispatched concurrently from 2–4
//! threads sharing one persistent `WorkerPool` — the lifetime shape of a
//! multi-job coordinator. Asserts:
//!
//! * **no deadlock** — the test completes (every fan-out call owns a
//!   private latch; worker queues interleave jobs from all callers);
//! * **zero steady-state spawns** — the thread count never moves after
//!   pool construction, no matter how many callers race;
//! * **bitwise correctness under interleaving** — every concurrent run
//!   matches its single-threaded scoped anchor exactly;
//! * **sticky-map consistency** — every sticky assignment names a valid
//!   lane, the map never grows beyond the distinct keys dispatched, and
//!   assignments stay stable once made (a second barrage re-hits them).

//!
//! The chaos suite below (PR 6) adds seeded fault schedules on top of the
//! same barrage machinery: recoverable faults (stragglers, jitter,
//! dropped-then-repaired publishes) must stay bitwise against the scoped
//! anchor; unrecoverable faults (lost publishes, worker panics) must
//! return the typed [`ramp::fault::RampError`] — never hang (every chaos
//! run sits under a test-level timeout guard) and never poison the pool.
//!
//! PR 7 removes the pool's exclusive blocking token, so parking
//! (cross-step) fan-outs now run concurrently as tenants in disjoint
//! epoch namespaces. The multi-tenant cases assert the new contract:
//! concurrent cross-step collectives truly interleave (`peak_tenants ≥
//! 2` in the tenant history), a stalled tenant's typed `StalledEpoch`
//! never perturbs a fault-free neighbor, and four tenants under salted
//! per-tenant chaos schedules (`FaultPlan::with_tenant`) stay bitwise
//! across the `RAMP_FAULT_SEED` matrix with zero deadlocks.

use ramp::collectives::arena::Pipeline;
use ramp::collectives::pool::{PoolSel, WorkerPool};
use ramp::collectives::ramp_x::RampX;
use ramp::collectives::MpiOp;
use ramp::engine::RampEngine;
use ramp::fault::recovery::RecoveryPolicy;
use ramp::fault::{FaultInjector, FaultPlan, RampError};
use ramp::rng::Xoshiro256;
use ramp::topology::ramp::RampParams;
use std::sync::Arc;
use std::time::Duration;

fn random_inputs(n: usize, elems: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut r = Xoshiro256::seed_from(seed);
    (0..n)
        .map(|_| (0..elems).map(|_| (r.next_below(2000) as f32) * 0.5 - 500.0).collect())
        .collect()
}

fn op_for(i: usize) -> MpiOp {
    match i % 4 {
        0 => MpiOp::AllReduce,
        1 => MpiOp::ReduceScatter,
        2 => MpiOp::AllToAll,
        _ => MpiOp::AllGather,
    }
}

/// One thread's barrage: `iters` collectives on the shared pool, each
/// checked bitwise against a fresh scoped (pool-less) anchor.
fn barrage(pool: &Arc<WorkerPool>, p: &RampParams, thread: usize, iters: usize) {
    let n = p.n_nodes();
    let pipeline = match thread % 3 {
        0 => Pipeline::off(),
        1 => Pipeline::fixed(3),
        _ => Pipeline::cross(3),
    };
    let x = RampX::new(p).with_pool(PoolSel::Forced(pool.clone())).with_pipeline(pipeline);
    for iter in 0..iters {
        let op = op_for(thread + iter);
        let elems = match op {
            MpiOp::AllGather => 7,
            _ => 2 * n,
        };
        let inputs = random_inputs(n, elems, 900 + (thread * 31 + iter) as u64);
        let mut got = inputs.clone();
        x.run(op, &mut got).unwrap();
        let mut want = inputs.clone();
        RampX::new(p).with_pool(PoolSel::Off).run(op, &mut want).unwrap();
        assert_eq!(got, want, "thread {thread} iteration {iter} ({}) diverged", op.name());
    }
}

#[test]
fn concurrent_collectives_share_one_pool_without_deadlock_or_spawns() {
    let pool = Arc::new(WorkerPool::new(3));
    let p = RampParams::fig8_example();
    let n = p.n_nodes();
    assert_eq!(pool.spawn_count(), 3, "construction is the only spawn");

    for n_threads in [2usize, 4] {
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let pool = &pool;
                let p = &p;
                s.spawn(move || barrage(pool, p, t, 3));
            }
        });
    }

    assert_eq!(pool.spawn_count(), 3, "steady state must never spawn");
    assert!(pool.fan_outs() > 0, "the pooled path must actually dispatch");
    assert!(pool.sticky_hits() > 0, "repeat subgroups must re-hit their lanes");
    // sticky keys are subgroup first-ranks, so the map is bounded by the
    // rank space no matter how many threads raced
    assert!(pool.sticky_size() <= n, "sticky map leaked keys: {}", pool.sticky_size());
    assert!(pool.sticky_lanes_valid(), "sticky assignment names an invalid lane");

    // stability: once assigned, a key's lane survives another barrage
    let lanes_before: Vec<Option<usize>> = (0..n).map(|k| pool.sticky_lane(k)).collect();
    let hits_before = pool.sticky_hits();
    std::thread::scope(|s| {
        for t in 0..3 {
            let pool = &pool;
            let p = &p;
            s.spawn(move || barrage(pool, p, t, 2));
        }
    });
    let lanes_after: Vec<Option<usize>> = (0..n).map(|k| pool.sticky_lane(k)).collect();
    for (k, (before, after)) in lanes_before.iter().zip(&lanes_after).enumerate() {
        if before.is_some() {
            assert_eq!(before, after, "sticky lane of key {k} drifted under interleaving");
        }
    }
    assert!(pool.sticky_hits() > hits_before, "second barrage must hit the sticky map");
    assert_eq!(pool.spawn_count(), 3);
}

#[test]
fn two_concurrent_cross_step_collectives_share_one_pool_event_driven() {
    // PR-7: the pool's exclusive blocking token is gone, so two whole
    // cross-step collectives dispatched concurrently are two parking
    // fan-outs in disjoint epoch namespaces — and they must truly
    // interleave, not take turns. Barrier-synced rounds run until the
    // tenant history records both programs live at once
    // (`peak_tenants >= 2`); a pool that secretly serialized parking
    // fan-outs would never produce such an entry. Cooperative lane jobs
    // make the overlap safe at any tenancy: a gated item parks at most
    // one bounded slice and then yields its worker back to the queue.
    // Still asserts zero steady-state spawns, exactly one fan-out (one
    // retired tenant) per collective, and bitwise correctness against
    // scoped anchors.
    let pool = Arc::new(WorkerPool::new(3));
    let p = RampParams::fig8_example();
    let n = p.n_nodes();
    assert_eq!(pool.spawn_count(), 3);
    pool.drain_tenant_history();
    let fan_outs_before = pool.fan_outs();
    let mut rounds = 0usize;
    let mut interleaved = false;
    while !interleaved {
        rounds += 1;
        assert!(rounds <= 50, "50 barrier-synced rounds never overlapped two tenants");
        let barrier = std::sync::Barrier::new(2);
        std::thread::scope(|s| {
            for t in 0..2usize {
                let pool = &pool;
                let p = &p;
                let barrier = &barrier;
                s.spawn(move || {
                    let op = if t == 0 { MpiOp::AllReduce } else { MpiOp::AllToAll };
                    let x = RampX::new(p)
                        .with_pool(PoolSel::Forced(pool.clone()))
                        .with_pipeline(Pipeline::cross(3));
                    let inputs = random_inputs(n, 2 * n, 700 + (t * 17 + rounds) as u64);
                    let mut got = inputs.clone();
                    barrier.wait();
                    x.run(op, &mut got).unwrap();
                    let mut want = inputs.clone();
                    RampX::new(p).with_pool(PoolSel::Off).run(op, &mut want).unwrap();
                    assert_eq!(got, want, "tenant {t} round {rounds} diverged");
                });
            }
        });
        let history = pool.drain_tenant_history();
        assert_eq!(history.len(), 2, "each cross-step collective retires exactly one tenant");
        assert!(history.iter().all(|st| st.items > 0), "a tenant retired without running");
        interleaved = history.iter().any(|st| st.peak_tenants >= 2);
    }
    assert_eq!(pool.spawn_count(), 3, "steady state must never spawn");
    assert_eq!(
        pool.fan_outs() - fan_outs_before,
        2 * rounds as u64,
        "each cross-step collective must be exactly one event fan-out"
    );
    assert_eq!(pool.active_tenants(), 0, "every tenant must have retired");
    assert!(pool.sticky_lanes_valid());
    assert!(pool.sticky_size() <= n, "sticky map leaked keys");
    // the aggregate blocked counter is monotone and readable; per-tenant
    // shares were snapshotted into the drained history above
    let _ = pool.lane_blocked_ns();
}

#[test]
fn concurrent_callers_on_the_global_pool_stay_correct() {
    // the production default: PoolSel::Global honors the inline
    // threshold, so drive payloads big enough to actually fan out
    let p = RampParams::new(2, 2, 4, 1);
    let n = p.n_nodes();
    let elems = 8192; // n·elems per step ≫ PAR_THRESHOLD_ELEMS
    let spawns_before = WorkerPool::global().spawn_count();
    std::thread::scope(|s| {
        for t in 0..3usize {
            let p = &p;
            s.spawn(move || {
                let inputs = random_inputs(n, elems, 40 + t as u64);
                let mut got = inputs.clone();
                RampX::new(p).run(MpiOp::AllReduce, &mut got).unwrap();
                let mut want = inputs.clone();
                RampX::new(p).with_pool(PoolSel::Off).run(MpiOp::AllReduce, &mut want).unwrap();
                assert_eq!(got, want, "thread {t} diverged on the global pool");
            });
        }
    });
    assert_eq!(
        WorkerPool::global().spawn_count(),
        spawns_before,
        "global pool spawned threads under concurrent collectives"
    );
    assert!(WorkerPool::global().sticky_lanes_valid());
}

// ---------------------------------------------------------------------------
// chaos suite: seeded fault schedules through the event-driven executors
// ---------------------------------------------------------------------------

/// Run `f` on a helper thread and panic if it does not finish within
/// `secs` — the suite's hang guard: a fault must surface as a bitwise
/// result or a typed error, never as a stuck test.
fn with_timeout<T: Send + 'static>(
    secs: u64,
    what: &str,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    let tag = what.to_string();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => v,
        Err(_) => panic!("{tag}: hung past the {secs}s chaos guard"),
    }
}

fn elems_for(op: MpiOp, n: usize) -> usize {
    match op {
        MpiOp::AllGather | MpiOp::Gather { .. } => 5,
        _ => 2 * n,
    }
}

#[test]
fn chaos_recoverable_faults_stay_bitwise_for_every_op() {
    // Seeded recoverable chaos (stragglers + jitter + dropped publishes
    // with a hot watchdog) across all nine ops and a seed matrix: every
    // run must match the fault-free scoped anchor bitwise, and every
    // recorded drop must have been watchdog-repaired. `RAMP_FAULT_SEED`
    // (the CI matrix axis) shifts the whole schedule — the fuzz axis
    // proving stragglers and jitter never influence results.
    let base = ramp::config::fault_seed_override().unwrap_or(11);
    with_timeout(240, "recoverable chaos", move || {
        let pool = Arc::new(WorkerPool::new(3));
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        let mut fired = (0u64, 0u64, 0u64); // (straggles, jitters, drops)
        for seed in [base, base.wrapping_add(1), base.wrapping_add(2)] {
            let inj = FaultInjector::new(FaultPlan::recoverable_chaos(seed));
            assert!(inj.plan().is_recoverable());
            let x = RampX::new(&p)
                .with_pool(PoolSel::Forced(pool.clone()))
                .with_pipeline(Pipeline::cross(3))
                .with_faults(inj.clone());
            for (i, op) in MpiOp::all().into_iter().enumerate() {
                let inputs =
                    random_inputs(n, elems_for(op, n), seed.wrapping_mul(31) + 500 + i as u64);
                let mut got = inputs.clone();
                x.run(op, &mut got)
                    .unwrap_or_else(|e| panic!("{} seed {seed}: {e:#}", op.name()));
                let mut want = inputs.clone();
                RampX::new(&p).with_pool(PoolSel::Off).run(op, &mut want).unwrap();
                assert_eq!(got, want, "{} seed {seed} diverged under chaos", op.name());
            }
            assert_eq!(
                inj.repairs(),
                inj.drops(),
                "seed {seed}: a dropped publish went unrepaired"
            );
            assert_eq!(inj.losses(), 0, "recoverable plan must not lose");
            assert_eq!(inj.panics(), 0, "recoverable plan must not panic");
            fired.0 += inj.straggles();
            fired.1 += inj.jitters();
            fired.2 += inj.drops();
        }
        // the chaos must actually chaos: across the seed matrix every
        // recoverable fault class fires at least once
        assert!(fired.0 > 0, "no straggler ever fired");
        assert!(fired.1 > 0, "no jitter ever fired");
        assert!(fired.2 > 0, "no publish was ever dropped");
        assert_eq!(pool.spawn_count(), 3, "chaos must not respawn lanes");
    });
}

#[test]
fn chaos_lost_publishes_return_typed_errors_never_hang() {
    // Certain loss (lose=1000‰) with a 40 ms watchdog: the collective
    // must fail with `RampError::StalledEpoch` naming the stalled
    // (rank, chunk, epoch) — within the guard, never a hang — and the
    // pool must keep serving fault-free collectives bitwise afterwards.
    with_timeout(120, "lost publishes", || {
        let pool = Arc::new(WorkerPool::new(3));
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        let inj = FaultInjector::new(FaultPlan {
            seed: 9,
            lose_permille: 1000,
            watchdog_ms: 40,
            ..FaultPlan::default()
        });
        let x = RampX::new(&p)
            .with_pool(PoolSel::Forced(pool.clone()))
            .with_pipeline(Pipeline::cross(3))
            .with_faults(inj.clone());
        let mut bufs = random_inputs(n, 2 * n, 77);
        let err = x.run(MpiOp::AllReduce, &mut bufs).expect_err("certain loss must fail");
        match err.downcast_ref::<RampError>() {
            Some(RampError::StalledEpoch { rank, chunk, epoch, waited_ms }) => {
                assert!(*rank < n, "stalled rank {rank} out of range");
                assert!(*epoch > 0, "stalled epoch must be a real step");
                assert!(
                    *waited_ms >= 40,
                    "watchdog fired before its deadline: {waited_ms} ms (chunk {chunk})"
                );
            }
            other => panic!("expected StalledEpoch, got {other:?} ({err:#})"),
        }
        assert!(inj.losses() > 0, "the loss schedule never fired");
        assert_eq!(inj.repairs(), 0, "losses leave no trace to repair");
        // pool survival: the same pool serves a fault-free run bitwise
        let clean = RampX::new(&p)
            .with_pool(PoolSel::Forced(pool.clone()))
            .with_pipeline(Pipeline::cross(3));
        let inputs = random_inputs(n, 2 * n, 78);
        let mut got = inputs.clone();
        clean.run(MpiOp::AllReduce, &mut got).unwrap();
        let mut want = inputs.clone();
        RampX::new(&p).with_pool(PoolSel::Off).run(MpiOp::AllReduce, &mut want).unwrap();
        assert_eq!(got, want, "pool damaged by a failed collective");
        assert_eq!(pool.spawn_count(), 3);
    });
}

#[test]
fn chaos_worker_panics_are_contained_and_typed() {
    // Certain panics: the fan-out must return `RampError::WorkerPanic`
    // (the injected payload captured in `detail`), the pool must stay
    // un-poisoned — zero thread deaths, zero steady-state respawns —
    // and subsequent collectives must be bitwise clean.
    with_timeout(120, "worker panics", || {
        let pool = Arc::new(WorkerPool::new(3));
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        let inj = FaultInjector::new(FaultPlan {
            seed: 4,
            panic_permille: 1000,
            ..FaultPlan::default()
        });
        let x = RampX::new(&p)
            .with_pool(PoolSel::Forced(pool.clone()))
            .with_pipeline(Pipeline::cross(3))
            .with_faults(inj.clone());
        let mut bufs = random_inputs(n, 2 * n, 13);
        let err = x.run(MpiOp::AllReduce, &mut bufs).expect_err("certain panics must fail");
        match err.downcast_ref::<RampError>() {
            Some(RampError::WorkerPanic { detail, .. }) => {
                assert!(
                    detail.contains("injected worker panic"),
                    "panic payload lost: {detail:?}"
                );
            }
            other => panic!("expected WorkerPanic, got {other:?} ({err:#})"),
        }
        assert!(inj.panics() > 0);
        assert_eq!(pool.contained_panics(), 0, "typed containment beat the last resort");
        // un-poisoned: same pool, fault-free, bitwise
        let clean = RampX::new(&p)
            .with_pool(PoolSel::Forced(pool.clone()))
            .with_pipeline(Pipeline::cross(3));
        for (i, op) in MpiOp::all().into_iter().enumerate() {
            let inputs = random_inputs(n, elems_for(op, n), 300 + i as u64);
            let mut got = inputs.clone();
            clean.run(op, &mut got).unwrap();
            let mut want = inputs.clone();
            RampX::new(&p).with_pool(PoolSel::Off).run(op, &mut want).unwrap();
            assert_eq!(got, want, "{} diverged after panic containment", op.name());
        }
        assert_eq!(pool.spawn_count(), 3, "panic containment must not cost threads");
    });
}

#[test]
fn chaos_one_stalled_tenant_leaves_neighbors_bitwise() {
    // Multi-tenant blast radius: tenant A runs under certain loss
    // (lose=1000‰, 40 ms watchdog) and must fail with its typed
    // `StalledEpoch`; tenant B shares the same pool concurrently,
    // fault-free, and must stay bitwise. A's watchdog abort tears down
    // only A's epoch namespace — B's gates are parked on a different
    // parker and never hear about it.
    with_timeout(120, "stalled tenant isolation", || {
        let pool = Arc::new(WorkerPool::new(3));
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        let barrier = std::sync::Barrier::new(2);
        std::thread::scope(|s| {
            let pool_a = pool.clone();
            let pool_b = pool.clone();
            let (pa, pb) = (&p, &p);
            let (barrier_a, barrier_b) = (&barrier, &barrier);
            s.spawn(move || {
                let inj = FaultInjector::new(
                    FaultPlan {
                        seed: 9,
                        lose_permille: 1000,
                        watchdog_ms: 40,
                        ..FaultPlan::default()
                    }
                    .with_tenant(1),
                );
                let x = RampX::new(pa)
                    .with_pool(PoolSel::Forced(pool_a))
                    .with_pipeline(Pipeline::cross(3))
                    .with_faults(inj.clone());
                let mut bufs = random_inputs(n, 2 * n, 177);
                barrier_a.wait();
                let err =
                    x.run(MpiOp::AllReduce, &mut bufs).expect_err("certain loss must fail");
                assert!(
                    matches!(
                        err.downcast_ref::<RampError>(),
                        Some(RampError::StalledEpoch { .. })
                    ),
                    "tenant A: expected StalledEpoch, got {err:#}"
                );
                assert!(inj.losses() > 0, "tenant A's loss schedule never fired");
            });
            s.spawn(move || {
                let x = RampX::new(pb)
                    .with_pool(PoolSel::Forced(pool_b))
                    .with_pipeline(Pipeline::cross(3));
                barrier_b.wait();
                for iter in 0..3usize {
                    let inputs = random_inputs(n, 2 * n, 560 + iter as u64);
                    let mut got = inputs.clone();
                    x.run(MpiOp::AllReduce, &mut got).unwrap_or_else(|e| {
                        panic!("tenant B iter {iter} caught A's failure: {e:#}")
                    });
                    let mut want = inputs.clone();
                    RampX::new(pb)
                        .with_pool(PoolSel::Off)
                        .run(MpiOp::AllReduce, &mut want)
                        .unwrap();
                    assert_eq!(got, want, "tenant B iter {iter} diverged next to a stall");
                }
            });
        });
        // pool healthy after the stall: fault-free run, still bitwise
        let inputs = random_inputs(n, 2 * n, 561);
        let mut got = inputs.clone();
        RampX::new(&p)
            .with_pool(PoolSel::Forced(pool.clone()))
            .with_pipeline(Pipeline::cross(3))
            .run(MpiOp::AllReduce, &mut got)
            .unwrap();
        let mut want = inputs.clone();
        RampX::new(&p).with_pool(PoolSel::Off).run(MpiOp::AllReduce, &mut want).unwrap();
        assert_eq!(got, want, "pool damaged by a stalled tenant");
        assert_eq!(pool.active_tenants(), 0, "the stalled tenant must still retire");
        assert_eq!(pool.spawn_count(), 3);
    });
}

#[test]
fn chaos_four_tenants_interleave_bitwise_across_seeds() {
    // Acceptance for the token removal: four concurrent cross-step
    // collectives on one shared pool — four parking fan-outs the old
    // blocking token would have run single-file — each tenant under its
    // own salted recoverable chaos schedule
    // (`FaultPlan::with_tenant(t)`), swept across a 3-seed matrix
    // (`RAMP_FAULT_SEED` shifts it in CI). Every run must stay bitwise
    // against its scoped anchor, every recorded drop must be
    // watchdog-repaired, nothing may deadlock (timeout guard) and the
    // pool must never spawn past construction.
    let base = ramp::config::fault_seed_override().unwrap_or(11);
    with_timeout(240, "four-tenant chaos", move || {
        let pool = Arc::new(WorkerPool::new(3));
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        for seed in [base, base.wrapping_add(1), base.wrapping_add(2)] {
            pool.drain_tenant_history();
            let barrier = std::sync::Barrier::new(4);
            std::thread::scope(|s| {
                for t in 0..4usize {
                    let pool = &pool;
                    let p = &p;
                    let barrier = &barrier;
                    s.spawn(move || {
                        let inj = FaultInjector::new(
                            FaultPlan::recoverable_chaos(seed).with_tenant(t as u64 + 1),
                        );
                        assert!(inj.plan().is_recoverable());
                        let x = RampX::new(p)
                            .with_pool(PoolSel::Forced(pool.clone()))
                            .with_pipeline(Pipeline::cross(3))
                            .with_faults(inj.clone());
                        barrier.wait();
                        for iter in 0..3usize {
                            let op = op_for(t + iter);
                            let inputs = random_inputs(
                                n,
                                elems_for(op, n),
                                seed.wrapping_mul(131) + (t * 7 + iter) as u64,
                            );
                            let mut got = inputs.clone();
                            x.run(op, &mut got).unwrap_or_else(|e| {
                                panic!("tenant {t} seed {seed} {}: {e:#}", op.name())
                            });
                            let mut want = inputs.clone();
                            RampX::new(p).with_pool(PoolSel::Off).run(op, &mut want).unwrap();
                            assert_eq!(
                                got, want,
                                "tenant {t} seed {seed} iter {iter} diverged under chaos"
                            );
                        }
                        assert_eq!(
                            inj.repairs(),
                            inj.drops(),
                            "tenant {t} seed {seed}: a dropped publish went unrepaired"
                        );
                    });
                }
            });
            let history = pool.tenant_history();
            assert!(
                history.iter().filter(|st| st.items > 0).count() >= 4,
                "seed {seed}: four tenants must retire with work done"
            );
        }
        assert_eq!(pool.active_tenants(), 0);
        assert_eq!(pool.spawn_count(), 3, "multi-tenant chaos must not spawn");
    });
}

// ---------------------------------------------------------------------------
// PR-8 recovery suite: the supervisory retry loop over the chaos machinery
// (`RAMP_RETRY` in the CI matrix arms the same policy on the CLI paths)
// ---------------------------------------------------------------------------

/// The CI recovery matrix (`RAMP_RETRY=on × RAMP_FAULT_SEED 41/97/223`)
/// swaps these tests' fallback policy for the env-armed one — the exact
/// policy the CLI's `--retry` would build — so the sweep exercises the
/// production spec-parsing path too. Tests that depend on a specific
/// budget (exhaustion, the resume sweep) keep their pinned policies.
fn policy_from_env_or(fallback: RecoveryPolicy) -> RecoveryPolicy {
    match ramp::config::retry_override() {
        Some(spec) => RecoveryPolicy::from_spec(&spec).expect("RAMP_RETRY spec"),
        None => fallback,
    }
}

#[test]
fn recovery_absorbs_midflight_trx_death_bitwise_for_every_op() {
    // A mid-flight transceiver death (`trx-at=1:1` — group 1 dies at lane
    // step 1) under the default retry policy: every op that reaches the
    // armed step must abort typed, quarantine the group, replan onto the
    // degraded fabric and complete **bitwise identical to the fault-free
    // anchor**. Ops whose lane program never reaches step 1 simply run
    // clean — bitwise either way. The per-attempt injector salt plus the
    // quarantine disarm guarantee convergence in exactly one retry.
    let base = ramp::config::fault_seed_override().unwrap_or(11);
    with_timeout(240, "trx-death recovery", move || {
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        let policy = policy_from_env_or(RecoveryPolicy::default());
        let mut recovered_ops = 0usize;
        for (i, op) in MpiOp::all().into_iter().enumerate() {
            let inputs = random_inputs(n, elems_for(op, n), base.wrapping_mul(17) + i as u64);
            let mut want = inputs.clone();
            let anchor = RampEngine::new(p.clone())
                .with_pipeline(Pipeline::cross(3))
                .execute(op, &mut want)
                .unwrap();
            let mut engine = RampEngine::new(p.clone())
                .with_pipeline(Pipeline::cross(3))
                .with_faults(FaultPlan {
                    seed: base,
                    trx_at: vec![(1, 1)],
                    watchdog_ms: 400,
                    ..FaultPlan::default()
                });
            let mut got = inputs.clone();
            let (run, stats) = engine
                .execute_with_recovery(op, &mut got, &policy)
                .unwrap_or_else(|e| panic!("{}: recovery failed: {e:#}", op.name()));
            assert_eq!(got, want, "{} diverged from the fault-free anchor", op.name());
            assert!(run.report.ok(), "{}: recovered run must be violation-free", op.name());
            assert!(stats.retries <= policy.max_retries as u64);
            if stats.recovered() {
                recovered_ops += 1;
                assert_eq!(
                    stats.quarantined_trx,
                    vec![1],
                    "{}: the dead group must be quarantined",
                    op.name()
                );
                assert!(
                    stats.backoff_virtual_s > 0.0,
                    "{}: a retry must price its backoff",
                    op.name()
                );
                // the replanned schedule routes nothing over the dead group,
                // yet conserves the anchor's wire bytes (Table 8)
                assert_eq!(
                    run.report.wire_bytes,
                    anchor.report.wire_bytes,
                    "{}: replan must conserve wire bytes",
                    op.name()
                );
            }
        }
        // the death must actually bite on the deep-program ops — a suite
        // where nothing ever recovered proves nothing
        assert!(recovered_ops >= 4, "only {recovered_ops} ops exercised recovery");
    });
}

#[test]
fn recovery_retries_seeded_panics_and_losses_to_success() {
    // Probabilistic retryable chaos: seeded worker panics and lost
    // publishes at moderate rates, swept over (permille, seed). Some
    // attempts abort, the salted injector shifts the sites every retry,
    // and the run must land in one of exactly two states: `Ok` bitwise
    // with the fault-free anchor, or a typed `RampError` after exhausting
    // the budget — never a hang (guard), never a corrupted result. The
    // sweep must produce at least one genuine recovery (abort → retry →
    // clean completion) for each fault class.
    let base = ramp::config::fault_seed_override().unwrap_or(11);
    with_timeout(240, "seeded retry chaos", move || {
        let p = RampParams::new(2, 2, 4, 1);
        let n = p.n_nodes();
        let policy =
            policy_from_env_or(RecoveryPolicy { max_retries: 4, ..RecoveryPolicy::default() });
        let inputs = random_inputs(n, 2 * n, 4242);
        let mut want = inputs.clone();
        RampEngine::new(p.clone())
            .with_pipeline(Pipeline::cross(3))
            .execute(MpiOp::AllReduce, &mut want)
            .unwrap();
        let mut recovered = (0u64, 0u64); // (panic, lose)
        let mut exhausted = 0u64;
        for permille in [2u32, 8, 25, 80] {
            for s in 0..10u64 {
                let seed = base.wrapping_mul(1009).wrapping_add(permille as u64 * 131 + s);
                for class in 0..2usize {
                    let plan = if class == 0 {
                        FaultPlan {
                            seed,
                            panic_permille: permille,
                            ..FaultPlan::default()
                        }
                    } else {
                        FaultPlan {
                            seed,
                            lose_permille: permille,
                            watchdog_ms: 40,
                            ..FaultPlan::default()
                        }
                    };
                    let mut engine = RampEngine::new(p.clone())
                        .with_pipeline(Pipeline::cross(3))
                        .with_faults(plan);
                    let mut got = inputs.clone();
                    match engine.execute_with_recovery(MpiOp::AllReduce, &mut got, &policy) {
                        Ok((_, stats)) => {
                            assert_eq!(
                                got, want,
                                "permille {permille} seed {seed} class {class}: \
                                 recovered result diverged"
                            );
                            if stats.recovered() {
                                if class == 0 {
                                    recovered.0 += 1;
                                } else {
                                    recovered.1 += 1;
                                }
                            }
                        }
                        Err(err) => {
                            assert!(
                                err.downcast_ref::<RampError>().is_some(),
                                "exhaustion must surface typed, got {err:#}"
                            );
                            exhausted += 1;
                        }
                    }
                }
            }
        }
        assert!(recovered.0 > 0, "no panic was ever retried to success");
        assert!(recovered.1 > 0, "no lost publish was ever retried to success");
        let _ = exhausted; // permitted outcome — it only has to stay typed
    });
}

#[test]
fn recovery_resume_resends_strictly_fewer_bytes_than_a_replay() {
    // Partial-progress resume, deterministically sequenced: a one-lane
    // forced pool drains every lane entry in schedule order, so for a
    // given seed the first panic site — and therefore the abort point —
    // is deterministic. Sweeping seeds under a mid-rate panic plan must
    // produce at least one abort where a chunk had already published its
    // final epoch: that run resumes instead of replaying, and the
    // acceptance inequality is checked on the wire — the resumed
    // schedule's bytes plus the carried (already-sent, never re-sent)
    // bytes reconstruct the anchor's Table-8 total exactly, so the
    // resumed attempt re-sent strictly fewer bytes than a full replay
    // would have (the wasted-bytes counter holds only the incomplete
    // chunks' re-sent traffic; a full replay would also waste the
    // carried bytes).
    let base = ramp::config::fault_seed_override().unwrap_or(11);
    with_timeout(300, "partial-progress resume", move || {
        let p = RampParams::new(2, 2, 4, 1);
        let n = p.n_nodes();
        let policy = RecoveryPolicy { max_retries: 6, ..RecoveryPolicy::default() };
        let inputs = random_inputs(n, 2 * n, 777);
        let mut want = inputs.clone();
        let anchor = RampEngine::new(p.clone())
            .with_pipeline(Pipeline::cross(3))
            .execute(MpiOp::AllReduce, &mut want)
            .unwrap();
        let anchor_wire = anchor.report.wire_bytes;
        let mut resumed_runs = 0u64;
        for permille in [10u32, 20, 35] {
            for s in 0..40u64 {
                let seed = base.wrapping_mul(313).wrapping_add(permille as u64 * 977 + s);
                let mut engine = RampEngine::new(p.clone())
                    .with_pipeline(Pipeline::cross(3))
                    .with_faults(FaultPlan {
                        seed,
                        panic_permille: permille,
                        ..FaultPlan::default()
                    });
                engine.pool = PoolSel::Forced(Arc::new(WorkerPool::new(0)));
                let mut got = inputs.clone();
                let Ok((run, stats)) =
                    engine.execute_with_recovery(MpiOp::AllReduce, &mut got, &policy)
                else {
                    continue; // exhausted budget — typed, covered elsewhere
                };
                assert_eq!(got, want, "seed {seed}: recovered result diverged");
                if stats.resumed_chunks == 0 {
                    continue;
                }
                resumed_runs += 1;
                assert!(stats.recovered());
                assert!(
                    stats.carried_bytes > 0,
                    "seed {seed}: a resumed chunk must carry its sent bytes"
                );
                // Table-8 conservation across the abort: resumed wire +
                // already-sent (carried) bytes == the anchor's total
                assert_eq!(
                    run.report.wire_bytes + stats.carried_bytes,
                    anchor_wire,
                    "seed {seed}: resume broke wire-byte conservation"
                );
                assert!(
                    run.report.wire_bytes < anchor_wire,
                    "seed {seed}: resume must re-send strictly fewer bytes"
                );
                // the wasted counter prices only incomplete chunks' re-sent
                // traffic — a replay would additionally waste the carried
                // bytes, so resume is strictly cheaper on the wire
                assert!(
                    stats.wasted_bytes
                        < stats.wasted_bytes + stats.carried_bytes,
                    "seed {seed}"
                );
                assert!(
                    stats.wasted_bytes <= anchor_wire * stats.retries,
                    "seed {seed}: wasted bytes exceed the aborted attempts' ceiling"
                );
            }
        }
        assert!(
            resumed_runs > 0,
            "no seed in the sweep ever resumed — the partial-progress path went untested"
        );
    });
}

#[test]
fn recovery_exhaustion_stays_typed_and_leaves_the_pool_clean() {
    // Certain panics under a tiny budget: every attempt aborts, the
    // budget exhausts, and the original typed error surfaces — never a
    // hang, never a poisoned pool (the same pool then serves a fault-free
    // collective bitwise).
    with_timeout(120, "typed exhaustion", || {
        let pool = Arc::new(WorkerPool::new(3));
        let p = RampParams::new(2, 2, 4, 1);
        let n = p.n_nodes();
        let policy = RecoveryPolicy { max_retries: 2, ..RecoveryPolicy::default() };
        let mut engine = RampEngine::new(p.clone())
            .with_pipeline(Pipeline::cross(3))
            .with_faults(FaultPlan { seed: 4, panic_permille: 1000, ..FaultPlan::default() });
        engine.pool = PoolSel::Forced(pool.clone());
        let mut bufs = random_inputs(n, 2 * n, 13);
        let err = engine
            .execute_with_recovery(MpiOp::AllReduce, &mut bufs, &policy)
            .expect_err("certain panics must exhaust the budget");
        assert!(
            matches!(err.downcast_ref::<RampError>(), Some(RampError::WorkerPanic { .. })),
            "expected WorkerPanic after exhaustion, got {err:#}"
        );
        // un-poisoned: same pool, fault-free, bitwise
        let inputs = random_inputs(n, 2 * n, 14);
        let mut got = inputs.clone();
        RampX::new(&p)
            .with_pool(PoolSel::Forced(pool.clone()))
            .with_pipeline(Pipeline::cross(3))
            .run(MpiOp::AllReduce, &mut got)
            .unwrap();
        let mut want = inputs.clone();
        RampX::new(&p).with_pool(PoolSel::Off).run(MpiOp::AllReduce, &mut want).unwrap();
        assert_eq!(got, want, "pool damaged by an exhausted recovery");
        assert_eq!(pool.spawn_count(), 3);
    });
}
