//! Pool stress net: whole collectives dispatched concurrently from 2–4
//! threads sharing one persistent `WorkerPool` — the lifetime shape of a
//! multi-job coordinator. Asserts:
//!
//! * **no deadlock** — the test completes (every fan-out call owns a
//!   private latch; worker queues interleave jobs from all callers);
//! * **zero steady-state spawns** — the thread count never moves after
//!   pool construction, no matter how many callers race;
//! * **bitwise correctness under interleaving** — every concurrent run
//!   matches its single-threaded scoped anchor exactly;
//! * **sticky-map consistency** — every sticky assignment names a valid
//!   lane, the map never grows beyond the distinct keys dispatched, and
//!   assignments stay stable once made (a second barrage re-hits them).

//!
//! The chaos suite below (PR 6) adds seeded fault schedules on top of the
//! same barrage machinery: recoverable faults (stragglers, jitter,
//! dropped-then-repaired publishes) must stay bitwise against the scoped
//! anchor; unrecoverable faults (lost publishes, worker panics) must
//! return the typed [`ramp::fault::RampError`] — never hang (every chaos
//! run sits under a test-level timeout guard) and never poison the pool.
//!
//! PR 7 removes the pool's exclusive blocking token, so parking
//! (cross-step) fan-outs now run concurrently as tenants in disjoint
//! epoch namespaces. The multi-tenant cases assert the new contract:
//! concurrent cross-step collectives truly interleave (`peak_tenants ≥
//! 2` in the tenant history), a stalled tenant's typed `StalledEpoch`
//! never perturbs a fault-free neighbor, and four tenants under salted
//! per-tenant chaos schedules (`FaultPlan::with_tenant`) stay bitwise
//! across the `RAMP_FAULT_SEED` matrix with zero deadlocks.

use ramp::collectives::arena::Pipeline;
use ramp::collectives::pool::{PoolSel, WorkerPool};
use ramp::collectives::ramp_x::RampX;
use ramp::collectives::MpiOp;
use ramp::fault::{FaultInjector, FaultPlan, RampError};
use ramp::rng::Xoshiro256;
use ramp::topology::ramp::RampParams;
use std::sync::Arc;
use std::time::Duration;

fn random_inputs(n: usize, elems: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut r = Xoshiro256::seed_from(seed);
    (0..n)
        .map(|_| (0..elems).map(|_| (r.next_below(2000) as f32) * 0.5 - 500.0).collect())
        .collect()
}

fn op_for(i: usize) -> MpiOp {
    match i % 4 {
        0 => MpiOp::AllReduce,
        1 => MpiOp::ReduceScatter,
        2 => MpiOp::AllToAll,
        _ => MpiOp::AllGather,
    }
}

/// One thread's barrage: `iters` collectives on the shared pool, each
/// checked bitwise against a fresh scoped (pool-less) anchor.
fn barrage(pool: &Arc<WorkerPool>, p: &RampParams, thread: usize, iters: usize) {
    let n = p.n_nodes();
    let pipeline = match thread % 3 {
        0 => Pipeline::off(),
        1 => Pipeline::fixed(3),
        _ => Pipeline::cross(3),
    };
    let x = RampX::new(p).with_pool(PoolSel::Forced(pool.clone())).with_pipeline(pipeline);
    for iter in 0..iters {
        let op = op_for(thread + iter);
        let elems = match op {
            MpiOp::AllGather => 7,
            _ => 2 * n,
        };
        let inputs = random_inputs(n, elems, 900 + (thread * 31 + iter) as u64);
        let mut got = inputs.clone();
        x.run(op, &mut got).unwrap();
        let mut want = inputs.clone();
        RampX::new(p).with_pool(PoolSel::Off).run(op, &mut want).unwrap();
        assert_eq!(got, want, "thread {thread} iteration {iter} ({}) diverged", op.name());
    }
}

#[test]
fn concurrent_collectives_share_one_pool_without_deadlock_or_spawns() {
    let pool = Arc::new(WorkerPool::new(3));
    let p = RampParams::fig8_example();
    let n = p.n_nodes();
    assert_eq!(pool.spawn_count(), 3, "construction is the only spawn");

    for n_threads in [2usize, 4] {
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let pool = &pool;
                let p = &p;
                s.spawn(move || barrage(pool, p, t, 3));
            }
        });
    }

    assert_eq!(pool.spawn_count(), 3, "steady state must never spawn");
    assert!(pool.fan_outs() > 0, "the pooled path must actually dispatch");
    assert!(pool.sticky_hits() > 0, "repeat subgroups must re-hit their lanes");
    // sticky keys are subgroup first-ranks, so the map is bounded by the
    // rank space no matter how many threads raced
    assert!(pool.sticky_size() <= n, "sticky map leaked keys: {}", pool.sticky_size());
    assert!(pool.sticky_lanes_valid(), "sticky assignment names an invalid lane");

    // stability: once assigned, a key's lane survives another barrage
    let lanes_before: Vec<Option<usize>> = (0..n).map(|k| pool.sticky_lane(k)).collect();
    let hits_before = pool.sticky_hits();
    std::thread::scope(|s| {
        for t in 0..3 {
            let pool = &pool;
            let p = &p;
            s.spawn(move || barrage(pool, p, t, 2));
        }
    });
    let lanes_after: Vec<Option<usize>> = (0..n).map(|k| pool.sticky_lane(k)).collect();
    for (k, (before, after)) in lanes_before.iter().zip(&lanes_after).enumerate() {
        if before.is_some() {
            assert_eq!(before, after, "sticky lane of key {k} drifted under interleaving");
        }
    }
    assert!(pool.sticky_hits() > hits_before, "second barrage must hit the sticky map");
    assert_eq!(pool.spawn_count(), 3);
}

#[test]
fn two_concurrent_cross_step_collectives_share_one_pool_event_driven() {
    // PR-7: the pool's exclusive blocking token is gone, so two whole
    // cross-step collectives dispatched concurrently are two parking
    // fan-outs in disjoint epoch namespaces — and they must truly
    // interleave, not take turns. Barrier-synced rounds run until the
    // tenant history records both programs live at once
    // (`peak_tenants >= 2`); a pool that secretly serialized parking
    // fan-outs would never produce such an entry. Cooperative lane jobs
    // make the overlap safe at any tenancy: a gated item parks at most
    // one bounded slice and then yields its worker back to the queue.
    // Still asserts zero steady-state spawns, exactly one fan-out (one
    // retired tenant) per collective, and bitwise correctness against
    // scoped anchors.
    let pool = Arc::new(WorkerPool::new(3));
    let p = RampParams::fig8_example();
    let n = p.n_nodes();
    assert_eq!(pool.spawn_count(), 3);
    pool.drain_tenant_history();
    let fan_outs_before = pool.fan_outs();
    let mut rounds = 0usize;
    let mut interleaved = false;
    while !interleaved {
        rounds += 1;
        assert!(rounds <= 50, "50 barrier-synced rounds never overlapped two tenants");
        let barrier = std::sync::Barrier::new(2);
        std::thread::scope(|s| {
            for t in 0..2usize {
                let pool = &pool;
                let p = &p;
                let barrier = &barrier;
                s.spawn(move || {
                    let op = if t == 0 { MpiOp::AllReduce } else { MpiOp::AllToAll };
                    let x = RampX::new(p)
                        .with_pool(PoolSel::Forced(pool.clone()))
                        .with_pipeline(Pipeline::cross(3));
                    let inputs = random_inputs(n, 2 * n, 700 + (t * 17 + rounds) as u64);
                    let mut got = inputs.clone();
                    barrier.wait();
                    x.run(op, &mut got).unwrap();
                    let mut want = inputs.clone();
                    RampX::new(p).with_pool(PoolSel::Off).run(op, &mut want).unwrap();
                    assert_eq!(got, want, "tenant {t} round {rounds} diverged");
                });
            }
        });
        let history = pool.drain_tenant_history();
        assert_eq!(history.len(), 2, "each cross-step collective retires exactly one tenant");
        assert!(history.iter().all(|st| st.items > 0), "a tenant retired without running");
        interleaved = history.iter().any(|st| st.peak_tenants >= 2);
    }
    assert_eq!(pool.spawn_count(), 3, "steady state must never spawn");
    assert_eq!(
        pool.fan_outs() - fan_outs_before,
        2 * rounds as u64,
        "each cross-step collective must be exactly one event fan-out"
    );
    assert_eq!(pool.active_tenants(), 0, "every tenant must have retired");
    assert!(pool.sticky_lanes_valid());
    assert!(pool.sticky_size() <= n, "sticky map leaked keys");
    // the aggregate blocked counter is monotone and readable; per-tenant
    // shares were snapshotted into the drained history above
    let _ = pool.lane_blocked_ns();
}

#[test]
fn concurrent_callers_on_the_global_pool_stay_correct() {
    // the production default: PoolSel::Global honors the inline
    // threshold, so drive payloads big enough to actually fan out
    let p = RampParams::new(2, 2, 4, 1);
    let n = p.n_nodes();
    let elems = 8192; // n·elems per step ≫ PAR_THRESHOLD_ELEMS
    let spawns_before = WorkerPool::global().spawn_count();
    std::thread::scope(|s| {
        for t in 0..3usize {
            let p = &p;
            s.spawn(move || {
                let inputs = random_inputs(n, elems, 40 + t as u64);
                let mut got = inputs.clone();
                RampX::new(p).run(MpiOp::AllReduce, &mut got).unwrap();
                let mut want = inputs.clone();
                RampX::new(p).with_pool(PoolSel::Off).run(MpiOp::AllReduce, &mut want).unwrap();
                assert_eq!(got, want, "thread {t} diverged on the global pool");
            });
        }
    });
    assert_eq!(
        WorkerPool::global().spawn_count(),
        spawns_before,
        "global pool spawned threads under concurrent collectives"
    );
    assert!(WorkerPool::global().sticky_lanes_valid());
}

// ---------------------------------------------------------------------------
// chaos suite: seeded fault schedules through the event-driven executors
// ---------------------------------------------------------------------------

/// Run `f` on a helper thread and panic if it does not finish within
/// `secs` — the suite's hang guard: a fault must surface as a bitwise
/// result or a typed error, never as a stuck test.
fn with_timeout<T: Send + 'static>(
    secs: u64,
    what: &str,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    let tag = what.to_string();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => v,
        Err(_) => panic!("{tag}: hung past the {secs}s chaos guard"),
    }
}

fn elems_for(op: MpiOp, n: usize) -> usize {
    match op {
        MpiOp::AllGather | MpiOp::Gather { .. } => 5,
        _ => 2 * n,
    }
}

#[test]
fn chaos_recoverable_faults_stay_bitwise_for_every_op() {
    // Seeded recoverable chaos (stragglers + jitter + dropped publishes
    // with a hot watchdog) across all nine ops and a seed matrix: every
    // run must match the fault-free scoped anchor bitwise, and every
    // recorded drop must have been watchdog-repaired. `RAMP_FAULT_SEED`
    // (the CI matrix axis) shifts the whole schedule — the fuzz axis
    // proving stragglers and jitter never influence results.
    let base = ramp::config::fault_seed_override().unwrap_or(11);
    with_timeout(240, "recoverable chaos", move || {
        let pool = Arc::new(WorkerPool::new(3));
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        let mut fired = (0u64, 0u64, 0u64); // (straggles, jitters, drops)
        for seed in [base, base.wrapping_add(1), base.wrapping_add(2)] {
            let inj = FaultInjector::new(FaultPlan::recoverable_chaos(seed));
            assert!(inj.plan().is_recoverable());
            let x = RampX::new(&p)
                .with_pool(PoolSel::Forced(pool.clone()))
                .with_pipeline(Pipeline::cross(3))
                .with_faults(inj.clone());
            for (i, op) in MpiOp::all().into_iter().enumerate() {
                let inputs =
                    random_inputs(n, elems_for(op, n), seed.wrapping_mul(31) + 500 + i as u64);
                let mut got = inputs.clone();
                x.run(op, &mut got)
                    .unwrap_or_else(|e| panic!("{} seed {seed}: {e:#}", op.name()));
                let mut want = inputs.clone();
                RampX::new(&p).with_pool(PoolSel::Off).run(op, &mut want).unwrap();
                assert_eq!(got, want, "{} seed {seed} diverged under chaos", op.name());
            }
            assert_eq!(
                inj.repairs(),
                inj.drops(),
                "seed {seed}: a dropped publish went unrepaired"
            );
            assert_eq!(inj.losses(), 0, "recoverable plan must not lose");
            assert_eq!(inj.panics(), 0, "recoverable plan must not panic");
            fired.0 += inj.straggles();
            fired.1 += inj.jitters();
            fired.2 += inj.drops();
        }
        // the chaos must actually chaos: across the seed matrix every
        // recoverable fault class fires at least once
        assert!(fired.0 > 0, "no straggler ever fired");
        assert!(fired.1 > 0, "no jitter ever fired");
        assert!(fired.2 > 0, "no publish was ever dropped");
        assert_eq!(pool.spawn_count(), 3, "chaos must not respawn lanes");
    });
}

#[test]
fn chaos_lost_publishes_return_typed_errors_never_hang() {
    // Certain loss (lose=1000‰) with a 40 ms watchdog: the collective
    // must fail with `RampError::StalledEpoch` naming the stalled
    // (rank, chunk, epoch) — within the guard, never a hang — and the
    // pool must keep serving fault-free collectives bitwise afterwards.
    with_timeout(120, "lost publishes", || {
        let pool = Arc::new(WorkerPool::new(3));
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        let inj = FaultInjector::new(FaultPlan {
            seed: 9,
            lose_permille: 1000,
            watchdog_ms: 40,
            ..FaultPlan::default()
        });
        let x = RampX::new(&p)
            .with_pool(PoolSel::Forced(pool.clone()))
            .with_pipeline(Pipeline::cross(3))
            .with_faults(inj.clone());
        let mut bufs = random_inputs(n, 2 * n, 77);
        let err = x.run(MpiOp::AllReduce, &mut bufs).expect_err("certain loss must fail");
        match err.downcast_ref::<RampError>() {
            Some(RampError::StalledEpoch { rank, chunk, epoch, waited_ms }) => {
                assert!(*rank < n, "stalled rank {rank} out of range");
                assert!(*epoch > 0, "stalled epoch must be a real step");
                assert!(
                    *waited_ms >= 40,
                    "watchdog fired before its deadline: {waited_ms} ms (chunk {chunk})"
                );
            }
            other => panic!("expected StalledEpoch, got {other:?} ({err:#})"),
        }
        assert!(inj.losses() > 0, "the loss schedule never fired");
        assert_eq!(inj.repairs(), 0, "losses leave no trace to repair");
        // pool survival: the same pool serves a fault-free run bitwise
        let clean = RampX::new(&p)
            .with_pool(PoolSel::Forced(pool.clone()))
            .with_pipeline(Pipeline::cross(3));
        let inputs = random_inputs(n, 2 * n, 78);
        let mut got = inputs.clone();
        clean.run(MpiOp::AllReduce, &mut got).unwrap();
        let mut want = inputs.clone();
        RampX::new(&p).with_pool(PoolSel::Off).run(MpiOp::AllReduce, &mut want).unwrap();
        assert_eq!(got, want, "pool damaged by a failed collective");
        assert_eq!(pool.spawn_count(), 3);
    });
}

#[test]
fn chaos_worker_panics_are_contained_and_typed() {
    // Certain panics: the fan-out must return `RampError::WorkerPanic`
    // (the injected payload captured in `detail`), the pool must stay
    // un-poisoned — zero thread deaths, zero steady-state respawns —
    // and subsequent collectives must be bitwise clean.
    with_timeout(120, "worker panics", || {
        let pool = Arc::new(WorkerPool::new(3));
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        let inj = FaultInjector::new(FaultPlan {
            seed: 4,
            panic_permille: 1000,
            ..FaultPlan::default()
        });
        let x = RampX::new(&p)
            .with_pool(PoolSel::Forced(pool.clone()))
            .with_pipeline(Pipeline::cross(3))
            .with_faults(inj.clone());
        let mut bufs = random_inputs(n, 2 * n, 13);
        let err = x.run(MpiOp::AllReduce, &mut bufs).expect_err("certain panics must fail");
        match err.downcast_ref::<RampError>() {
            Some(RampError::WorkerPanic { detail, .. }) => {
                assert!(
                    detail.contains("injected worker panic"),
                    "panic payload lost: {detail:?}"
                );
            }
            other => panic!("expected WorkerPanic, got {other:?} ({err:#})"),
        }
        assert!(inj.panics() > 0);
        assert_eq!(pool.contained_panics(), 0, "typed containment beat the last resort");
        // un-poisoned: same pool, fault-free, bitwise
        let clean = RampX::new(&p)
            .with_pool(PoolSel::Forced(pool.clone()))
            .with_pipeline(Pipeline::cross(3));
        for (i, op) in MpiOp::all().into_iter().enumerate() {
            let inputs = random_inputs(n, elems_for(op, n), 300 + i as u64);
            let mut got = inputs.clone();
            clean.run(op, &mut got).unwrap();
            let mut want = inputs.clone();
            RampX::new(&p).with_pool(PoolSel::Off).run(op, &mut want).unwrap();
            assert_eq!(got, want, "{} diverged after panic containment", op.name());
        }
        assert_eq!(pool.spawn_count(), 3, "panic containment must not cost threads");
    });
}

#[test]
fn chaos_one_stalled_tenant_leaves_neighbors_bitwise() {
    // Multi-tenant blast radius: tenant A runs under certain loss
    // (lose=1000‰, 40 ms watchdog) and must fail with its typed
    // `StalledEpoch`; tenant B shares the same pool concurrently,
    // fault-free, and must stay bitwise. A's watchdog abort tears down
    // only A's epoch namespace — B's gates are parked on a different
    // parker and never hear about it.
    with_timeout(120, "stalled tenant isolation", || {
        let pool = Arc::new(WorkerPool::new(3));
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        let barrier = std::sync::Barrier::new(2);
        std::thread::scope(|s| {
            let pool_a = pool.clone();
            let pool_b = pool.clone();
            let (pa, pb) = (&p, &p);
            let (barrier_a, barrier_b) = (&barrier, &barrier);
            s.spawn(move || {
                let inj = FaultInjector::new(
                    FaultPlan {
                        seed: 9,
                        lose_permille: 1000,
                        watchdog_ms: 40,
                        ..FaultPlan::default()
                    }
                    .with_tenant(1),
                );
                let x = RampX::new(pa)
                    .with_pool(PoolSel::Forced(pool_a))
                    .with_pipeline(Pipeline::cross(3))
                    .with_faults(inj.clone());
                let mut bufs = random_inputs(n, 2 * n, 177);
                barrier_a.wait();
                let err =
                    x.run(MpiOp::AllReduce, &mut bufs).expect_err("certain loss must fail");
                assert!(
                    matches!(
                        err.downcast_ref::<RampError>(),
                        Some(RampError::StalledEpoch { .. })
                    ),
                    "tenant A: expected StalledEpoch, got {err:#}"
                );
                assert!(inj.losses() > 0, "tenant A's loss schedule never fired");
            });
            s.spawn(move || {
                let x = RampX::new(pb)
                    .with_pool(PoolSel::Forced(pool_b))
                    .with_pipeline(Pipeline::cross(3));
                barrier_b.wait();
                for iter in 0..3usize {
                    let inputs = random_inputs(n, 2 * n, 560 + iter as u64);
                    let mut got = inputs.clone();
                    x.run(MpiOp::AllReduce, &mut got).unwrap_or_else(|e| {
                        panic!("tenant B iter {iter} caught A's failure: {e:#}")
                    });
                    let mut want = inputs.clone();
                    RampX::new(pb)
                        .with_pool(PoolSel::Off)
                        .run(MpiOp::AllReduce, &mut want)
                        .unwrap();
                    assert_eq!(got, want, "tenant B iter {iter} diverged next to a stall");
                }
            });
        });
        // pool healthy after the stall: fault-free run, still bitwise
        let inputs = random_inputs(n, 2 * n, 561);
        let mut got = inputs.clone();
        RampX::new(&p)
            .with_pool(PoolSel::Forced(pool.clone()))
            .with_pipeline(Pipeline::cross(3))
            .run(MpiOp::AllReduce, &mut got)
            .unwrap();
        let mut want = inputs.clone();
        RampX::new(&p).with_pool(PoolSel::Off).run(MpiOp::AllReduce, &mut want).unwrap();
        assert_eq!(got, want, "pool damaged by a stalled tenant");
        assert_eq!(pool.active_tenants(), 0, "the stalled tenant must still retire");
        assert_eq!(pool.spawn_count(), 3);
    });
}

#[test]
fn chaos_four_tenants_interleave_bitwise_across_seeds() {
    // Acceptance for the token removal: four concurrent cross-step
    // collectives on one shared pool — four parking fan-outs the old
    // blocking token would have run single-file — each tenant under its
    // own salted recoverable chaos schedule
    // (`FaultPlan::with_tenant(t)`), swept across a 3-seed matrix
    // (`RAMP_FAULT_SEED` shifts it in CI). Every run must stay bitwise
    // against its scoped anchor, every recorded drop must be
    // watchdog-repaired, nothing may deadlock (timeout guard) and the
    // pool must never spawn past construction.
    let base = ramp::config::fault_seed_override().unwrap_or(11);
    with_timeout(240, "four-tenant chaos", move || {
        let pool = Arc::new(WorkerPool::new(3));
        let p = RampParams::fig8_example();
        let n = p.n_nodes();
        for seed in [base, base.wrapping_add(1), base.wrapping_add(2)] {
            pool.drain_tenant_history();
            let barrier = std::sync::Barrier::new(4);
            std::thread::scope(|s| {
                for t in 0..4usize {
                    let pool = &pool;
                    let p = &p;
                    let barrier = &barrier;
                    s.spawn(move || {
                        let inj = FaultInjector::new(
                            FaultPlan::recoverable_chaos(seed).with_tenant(t as u64 + 1),
                        );
                        assert!(inj.plan().is_recoverable());
                        let x = RampX::new(p)
                            .with_pool(PoolSel::Forced(pool.clone()))
                            .with_pipeline(Pipeline::cross(3))
                            .with_faults(inj.clone());
                        barrier.wait();
                        for iter in 0..3usize {
                            let op = op_for(t + iter);
                            let inputs = random_inputs(
                                n,
                                elems_for(op, n),
                                seed.wrapping_mul(131) + (t * 7 + iter) as u64,
                            );
                            let mut got = inputs.clone();
                            x.run(op, &mut got).unwrap_or_else(|e| {
                                panic!("tenant {t} seed {seed} {}: {e:#}", op.name())
                            });
                            let mut want = inputs.clone();
                            RampX::new(p).with_pool(PoolSel::Off).run(op, &mut want).unwrap();
                            assert_eq!(
                                got, want,
                                "tenant {t} seed {seed} iter {iter} diverged under chaos"
                            );
                        }
                        assert_eq!(
                            inj.repairs(),
                            inj.drops(),
                            "tenant {t} seed {seed}: a dropped publish went unrepaired"
                        );
                    });
                }
            });
            let history = pool.tenant_history();
            assert!(
                history.iter().filter(|st| st.items > 0).count() >= 4,
                "seed {seed}: four tenants must retire with work done"
            );
        }
        assert_eq!(pool.active_tenants(), 0);
        assert_eq!(pool.spawn_count(), 3, "multi-tenant chaos must not spawn");
    });
}
