//! Bounded-memory acceptance for lazy sharded plan generation: plan,
//! transcode, and estimate a full all-reduce at 4,096 / 16,384 / 65,536
//! ranks under an allocation-counting global allocator, and assert the
//! peak is sub-linear in rank count (the eager path materializes
//! ~12.6M `Transfer`s at 65,536 ranks; the streamed path must not).
//!
//! This file intentionally holds a SINGLE test function: `cargo test`
//! runs tests in one binary on parallel threads, and concurrent tests
//! would pollute the shared peak counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use ramp::collectives::arena::Pipeline;
use ramp::collectives::stream::StreamPlan;
use ramp::estimator::collective_time::streamed_schedule_time;
use ramp::topology::ramp::RampParams;
use ramp::transcoder::transcode_stream;

/// Byte-counting wrapper around the system allocator. `realloc` and
/// `alloc_zeroed` use the `GlobalAlloc` defaults, which route through
/// `alloc`/`dealloc`, so every live byte is counted.
struct Counting;

static CURRENT: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let sz = layout.size() as u64;
            let cur = CURRENT.fetch_add(sz, Ordering::Relaxed) + sz;
            PEAK.fetch_max(cur, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        CURRENT.fetch_sub(layout.size() as u64, Ordering::Relaxed);
    }
}

#[global_allocator]
static ALLOC: Counting = Counting;

/// Run `f`, returning its result and the peak number of bytes allocated
/// ABOVE the live set at entry.
fn measure_peak<T>(f: impl FnOnce() -> T) -> (T, u64) {
    let base = CURRENT.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    let out = f();
    (out, PEAK.load(Ordering::Relaxed).saturating_sub(base))
}

const MIB: u64 = 1 << 20;

#[test]
fn bounded_memory_plan_transcode_estimate_at_scale() {
    // (fabric, ranks): two intermediate scales plus the paper's full
    // 65,536-node machine (x = J = 32, Λ = 64).
    let scales = [
        (RampParams::new(16, 16, 16, 1), 4096usize),
        (RampParams::new(16, 16, 64, 1), 16384usize),
        (RampParams::max_scale(), 65536usize),
    ];

    let mut peaks = Vec::new();
    for (p, n) in &scales {
        assert_eq!(p.n_nodes(), *n);
        let m = n * 16;
        let ((summary, sched, time), peak) = measure_peak(|| {
            let plan = StreamPlan::all_reduce(p, m, Pipeline::off()).unwrap();
            let sched = transcode_stream(p, &plan, |_| {}).unwrap();
            let time = streamed_schedule_time(p, &sched);
            (plan.summary(), sched, time)
        });

        // the folded schedule must agree with the plan's closed forms
        assert_eq!(sched.total_bytes, summary.total_wire_bytes, "n={n}");
        assert_eq!(sched.n_rounds, summary.n_rounds, "n={n}");
        assert!(summary.n_transfers > 0 && sched.n_instructions >= summary.n_transfers, "n={n}");
        assert!(time.h2h > 0.0 && time.h2t > 0.0 && time.total().is_finite(), "n={n}");

        // absolute ceiling: the whole pipeline fits in a few MiB even at
        // 65,536 ranks (the eager plan alone would need gigabytes)
        assert!(peak < 8 * MIB, "n={n}: peak {peak} bytes exceeds 8 MiB");
        peaks.push(peak);
    }

    // sub-linear growth: ranks scale 16x from the first fabric to the
    // third; allow less than 8x memory growth (plus fixed slack for
    // allocator noise). In practice the peak is near-constant.
    assert!(
        peaks[2] < peaks[0] * 8 + MIB,
        "peak grew super-linearly: {peaks:?}"
    );
    assert!(
        peaks[1] < peaks[0] * 4 + MIB,
        "peak grew super-linearly: {peaks:?}"
    );
}
