//! Streaming-vs-eager differential net for lazy sharded plan generation:
//! the bounded-memory scale path (`collectives::stream` +
//! `transcoder::transcode_stream`) must agree with the eager builders
//! **exactly** on the 5 differential fabrics — materialized plans
//! field-for-field, folded summaries against materialized totals, NIC
//! instruction streams instruction-for-instruction (a claim strictly
//! stronger than the multiset equality the scale work needs), and the
//! sharded per-slab executor bitwise on the data plane.

use ramp::collectives::arena::Pipeline;
use ramp::collectives::plan::CollectivePlan;
use ramp::collectives::ramp_x::RampX;
use ramp::collectives::stream::{ShardedExchange, StreamPlan};
use ramp::collectives::MpiOp;
use ramp::estimator::collective_time::streamed_schedule_time;
use ramp::rng::Xoshiro256;
use ramp::topology::ramp::RampParams;
use ramp::transcoder::{transcode_plan, transcode_stream, NicInstruction};

/// The 5 differential fabrics of the executor test net (16–54 nodes,
/// covering inactive step-3/4 shapes and multi-round step 4).
fn fabrics() -> Vec<RampParams> {
    vec![
        RampParams::new(2, 2, 4, 1),
        RampParams::fig8_example(),
        RampParams::new(4, 2, 4, 1),
        RampParams::new(3, 1, 3, 1),
        RampParams::new(2, 2, 8, 1),
    ]
}

fn pipelines() -> Vec<Pipeline> {
    vec![Pipeline::off(), Pipeline::fixed(3), Pipeline::auto()]
}

fn exchange_cases(n: usize) -> Vec<(MpiOp, usize)> {
    vec![
        (MpiOp::ReduceScatter, 2 * n),
        (MpiOp::AllGather, 3),
        (MpiOp::AllReduce, n),
    ]
}

fn random_inputs(p: &RampParams, elems: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut r = Xoshiro256::seed_from(seed);
    (0..p.n_nodes())
        .map(|_| (0..elems).map(|_| (r.next_below(1000) as f32) - 500.0).collect())
        .collect()
}

/// Run the eager executor purely to harvest its emitted plan.
fn eager_plan(p: &RampParams, op: MpiOp, m: usize, pipeline: Pipeline) -> CollectivePlan {
    let mut bufs = random_inputs(p, m, 7);
    RampX::new(p).with_pipeline(pipeline).run(op, &mut bufs).unwrap()
}

fn assert_plans_equal(a: &CollectivePlan, b: &CollectivePlan, ctx: &str) {
    assert_eq!(a.steps.len(), b.steps.len(), "{ctx}: step count");
    for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
        assert_eq!(sa.label, sb.label, "{ctx}: step {i} label");
        assert_eq!(sa.step, sb.step, "{ctx}: step {i} step id");
        assert_eq!(sa.reduce_sources, sb.reduce_sources, "{ctx}: step {i} reduce_sources");
        assert_eq!(sa.reduce_bytes, sb.reduce_bytes, "{ctx}: step {i} reduce_bytes");
        assert_eq!(sa.trx_q, sb.trx_q, "{ctx}: step {i} trx_q");
        assert_eq!(sa.n_chunks, sb.n_chunks, "{ctx}: step {i} n_chunks");
        assert_eq!(sa.lane_aligned, sb.lane_aligned, "{ctx}: step {i} lane_aligned");
        assert_eq!(sa.rounds.len(), sb.rounds.len(), "{ctx}: step {i} round count");
        for (r, (ra, rb)) in sa.rounds.iter().zip(&sb.rounds).enumerate() {
            assert_eq!(ra.transfers, rb.transfers, "{ctx}: step {i} round {r}");
        }
    }
}

type InsKey = (usize, usize, usize, usize, (usize, usize, usize), usize, u64, u64, u64, Vec<usize>);

fn ins_key(p: &RampParams, i: &NicInstruction) -> InsKey {
    (
        i.src.g,
        i.src.j,
        i.src.lambda,
        i.trx,
        (i.subnet.src_group, i.subnet.dst_group, i.subnet.trx),
        i.wavelength,
        i.slot,
        i.n_slots,
        i.bytes,
        i.dsts.iter().map(|d| d.flat(p)).collect(),
    )
}

#[test]
fn materialized_stream_plans_equal_eager_plans() {
    for p in fabrics() {
        let n = p.n_nodes();
        for pipeline in pipelines() {
            for (op, m) in exchange_cases(n) {
                let eager = eager_plan(&p, op, m, pipeline);
                let stream = StreamPlan::for_op(&p, op, m, pipeline).unwrap();
                let ctx = format!("{op:?} {p:?} pipeline {pipeline:?}");
                assert_plans_equal(&stream.materialize(&p), &eager, &ctx);
                assert_eq!(stream.summary(), eager.summary(), "{ctx}: folded summary");
            }
        }
    }
}

#[test]
fn streamed_transcode_matches_eager_instruction_for_instruction() {
    for p in fabrics() {
        let n = p.n_nodes();
        for pipeline in pipelines() {
            for (op, m) in exchange_cases(n) {
                let ctx = format!("{op:?} {p:?} pipeline {pipeline:?}");
                let eager = transcode_plan(&p, &eager_plan(&p, op, m, pipeline)).unwrap();
                let stream = StreamPlan::for_op(&p, op, m, pipeline).unwrap();
                let mut streamed = Vec::new();
                let sum = transcode_stream(&p, &stream, |i| streamed.push(i)).unwrap();
                // folded accounting vs the eager schedule
                assert_eq!(sum.total_slots, eager.total_slots, "{ctx}: total_slots");
                assert_eq!(sum.h2h_rounds, eager.h2h_rounds, "{ctx}: h2h_rounds");
                assert_eq!(sum.n_rounds, eager.round_ends.len(), "{ctx}: n_rounds");
                assert_eq!(
                    sum.n_instructions,
                    eager.instructions.len() as u64,
                    "{ctx}: instruction count"
                );
                let eager_bytes: u64 = eager.instructions.iter().map(|i| i.bytes).sum();
                assert_eq!(sum.total_bytes, eager_bytes, "{ctx}: byte total");
                assert_eq!(
                    sum.total_bytes,
                    stream.summary().total_wire_bytes,
                    "{ctx}: schedule bytes vs plan closed form"
                );
                // the instruction stream itself: same order, same content
                let ek: Vec<_> = eager.instructions.iter().map(|i| ins_key(&p, i)).collect();
                let sk: Vec<_> = streamed.iter().map(|i| ins_key(&p, i)).collect();
                assert_eq!(sk, ek, "{ctx}: instruction stream");
            }
        }
    }
}

#[test]
fn streamed_transcode_matches_under_broadcast_and_select() {
    // RouteSelect is the default, so the tests above exercise the dense
    // step-4 striping; pin the Broadcast&Select trx-group formula too
    for p in fabrics() {
        let p = p.with_broadcast_select();
        let n = p.n_nodes();
        let stream = StreamPlan::all_reduce(&p, n, Pipeline::off()).unwrap();
        let eager = transcode_plan(&p, &eager_plan(&p, MpiOp::AllReduce, n, Pipeline::off()))
            .unwrap();
        let mut streamed = Vec::new();
        let sum = transcode_stream(&p, &stream, |i| streamed.push(i)).unwrap();
        assert_eq!(sum.total_slots, eager.total_slots, "{p:?}");
        assert_eq!(sum.n_instructions, eager.instructions.len() as u64, "{p:?}");
        let ek: Vec<_> = eager.instructions.iter().map(|i| ins_key(&p, i)).collect();
        let sk: Vec<_> = streamed.iter().map(|i| ins_key(&p, i)).collect();
        assert_eq!(sk, ek, "{p:?}: R&S instruction stream");
    }
}

#[test]
fn sharded_executor_is_bitwise_equal_to_eager() {
    for p in fabrics() {
        let n = p.n_nodes();
        for pipeline in [Pipeline::off(), Pipeline::fixed(4)] {
            for (op, m) in exchange_cases(n) {
                let inputs = random_inputs(&p, m, 21);
                let mut eager = inputs.clone();
                RampX::new(&p).with_pipeline(pipeline).run(op, &mut eager).unwrap();
                let mut sharded = inputs.clone();
                ShardedExchange::new(&p)
                    .with_pipeline(pipeline)
                    .with_batch(2)
                    .run(op, &mut sharded)
                    .unwrap();
                assert_eq!(sharded, eager, "{op:?} {p:?} pipeline {pipeline:?}");
            }
        }
    }
}

#[test]
fn lane_shapes_reproduce_from_plan_schedules() {
    use ramp::transcoder::lanes::LaneSchedule;
    for p in fabrics() {
        let n = p.n_nodes();
        for pipeline in pipelines() {
            for (op, m) in exchange_cases(n) {
                let stream = StreamPlan::for_op(&p, op, m, pipeline).unwrap();
                let of_shapes = LaneSchedule::from_shapes(&stream.lane_shapes());
                let materialized = stream.materialize(&p);
                let of_plan = LaneSchedule::from_plan(&materialized);
                of_shapes.validate(&materialized).unwrap();
                assert_eq!(of_shapes.tasks, of_plan.tasks, "{op:?} {p:?}");
                assert_eq!(of_shapes.deps, of_plan.deps, "{op:?} {p:?}");
                assert_eq!(of_shapes.waves, of_plan.waves, "{op:?} {p:?}");
            }
        }
    }
}

#[test]
fn streamed_estimate_is_finite_and_consistent() {
    for p in fabrics() {
        let n = p.n_nodes();
        let stream = StreamPlan::all_reduce(&p, 4 * n, Pipeline::off()).unwrap();
        let sum = transcode_stream(&p, &stream, |_| {}).unwrap();
        let t = streamed_schedule_time(&p, &sum);
        assert!(t.h2h > 0.0 && t.h2t > 0.0 && t.total().is_finite(), "{p:?}");
        // H2H prices exactly the latency-bearing rounds
        let per_round = p.propagation + p.io_latency;
        assert!((t.h2h - sum.h2h_rounds as f64 * per_round).abs() < 1e-12, "{p:?}");
    }
}
