#!/usr/bin/env python3
"""Bench-regression gate: diff a fresh BENCH_collectives.json against the
committed baseline and fail on slowdown in the guarded rows.

Usage:
    bench_regression.py BASELINE.json NEW.json [--threshold 0.10]
                        [--filter "[arena pooled cross-step]"]

Rows are matched by exact name; only rows whose name contains the filter
substring are guarded (default: the `[arena pooled cross-step]` columns —
the perf this PR series defends). A guarded row regresses when its
ns_per_iter exceeds the baseline by more than the threshold fraction.

Unguarded sections ride along without gating. In particular the
`[recovery]` rows (the PR-8 supervisory retry loop: clean engine vs
supervised fault-free vs trx-death + replan + retry) measure fault-path
latency, which is noisy by design and absent from the committed
placeholder baseline — they are listed informationally when present in
both files, and their absence from either file is never an error. The
`[plan-gen]` rows (PR-9 lazy sharded plan generation + streaming
transcode throughput at 4k/16k/65k ranks) are likewise informational:
plan generation is a setup cost, not the defended steady-state path.
So are the `[elastic]` rows (PR-10 rank-death reformation: the
remap + reconcile + replan pass over the survivors) — reformation is a
rare failure-path cost, not steady state.

Exits 0 (with a note) when the baseline is still the placeholder no
toolchain host has replaced yet, when it contains no guarded rows, or when
nothing regressed; exits 1 listing every regressed row otherwise.
"""

# unguarded-but-listed sections: shown for the record, never gated
INFORMATIONAL_SECTIONS = ["[recovery]", "[plan-gen]", "[elastic]"]

import argparse
import json
import sys


def load_rows(path):
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, list):
        sys.exit(f"error: {path} is not a JSON array of bench rows")
    return data


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="allowed fractional slowdown (default 0.10)")
    ap.add_argument("--filter", default="[arena pooled cross-step]",
                    help="guard only rows whose name contains this substring")
    args = ap.parse_args()

    baseline = load_rows(args.baseline)
    if any("PLACEHOLDER" in str(row.get("name", "")) for row in baseline):
        # a placeholder baseline means the perf gate is NOT running — say
        # so loudly (GitHub Actions surfaces ::warning:: annotations on
        # the run summary) instead of green-skipping in silence
        msg = (f"bench-regression gate is INACTIVE: baseline "
               f"{args.baseline} is still the committed placeholder — no "
               f"toolchain host has recorded a real baseline yet. Run "
               f"`make bench-json` on a quiet host and commit the result "
               f"to arm the gate.")
        print(f"::warning title=bench-regression gate inactive::{msg}")
        print(f"bench-regression: WARNING: {msg}")
        return 0
    base = {row["name"]: row for row in baseline
            if args.filter in str(row.get("name", ""))
            and row.get("ns_per_iter") is not None}
    if not base:
        print(f"bench-regression: baseline has no rows matching "
              f"{args.filter!r} — skipping")
        return 0

    new = {row["name"]: row for row in load_rows(args.new)
           if row.get("ns_per_iter") is not None}
    regressed, checked, missing = [], 0, []
    for name, brow in sorted(base.items()):
        nrow = new.get(name)
        if nrow is None:
            missing.append(name)
            continue
        checked += 1
        b, n = float(brow["ns_per_iter"]), float(nrow["ns_per_iter"])
        ratio = n / b if b > 0 else float("inf")
        status = "ok" if ratio <= 1.0 + args.threshold else "REGRESSED"
        print(f"bench-regression: {name}: {b:.0f} -> {n:.0f} ns/iter "
              f"({ratio:.3f}x) {status}")
        if status == "REGRESSED":
            regressed.append((name, ratio))
    for name in missing:
        print(f"bench-regression: guarded row {name!r} missing from the "
              "new run (renamed? keep names stable)")
    if missing:
        # a silently vanished guarded row would disable the gate exactly
        # when it matters — treat it as a failure, not a warning
        print(f"bench-regression: {len(missing)} guarded rows missing — "
              "update the committed baseline together with any rename")
        return 1

    # informational sections: print the comparison when a row exists in
    # both files, stay silent (and green) otherwise — the committed
    # placeholder predates these sections entirely
    for tag in INFORMATIONAL_SECTIONS:
        info = {row["name"]: row for row in baseline
                if tag in str(row.get("name", ""))
                and row.get("ns_per_iter") is not None}
        for name, brow in sorted(info.items()):
            nrow = new.get(name)
            if nrow is None:
                continue
            b, n = float(brow["ns_per_iter"]), float(nrow["ns_per_iter"])
            ratio = n / b if b > 0 else float("inf")
            print(f"bench-regression: {name}: {b:.0f} -> {n:.0f} ns/iter "
                  f"({ratio:.3f}x) informational (not gated)")

    if regressed:
        print(f"bench-regression: {len(regressed)} of {checked} guarded rows "
              f"slowed down by more than {args.threshold:.0%}:")
        for name, ratio in regressed:
            print(f"  {ratio:.3f}x  {name}")
        return 1
    print(f"bench-regression: {checked} guarded rows within "
          f"{args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
